//! Integration: full synchronous-SGD training through the coordinator —
//! including the Fig 5 convergence-equivalence property, the paper's
//! central correctness claim ("the multi-threaded, multi-node parallel
//! implementation is equivalent to a single-node single-threaded serial
//! implementation").

use pcl_dnn::runtime::Runtime;
use pcl_dnn::trainer::{train, TrainConfig};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new("artifacts").expect("runtime"))
}

fn cfg(model: &str, workers: usize, mb: usize, steps: u64) -> TrainConfig {
    TrainConfig {
        model: model.into(),
        workers,
        global_mb: mb,
        steps,
        lr: 0.01,
        momentum: 0.0,
        seed: 0,
        log_every: 0,
        eval_every: 0,
        optimizer: "sgd".into(),
        prefetch: 8,
        plan: None,
        ..TrainConfig::default()
    }
}

#[test]
fn fig5_worker_counts_produce_equivalent_convergence() {
    let Some(mut rt) = runtime() else { return };
    let steps = 12;
    let run1 = train(&mut rt, &cfg("vgg_tiny", 1, 16, steps)).unwrap();
    let run2 = train(&mut rt, &cfg("vgg_tiny", 2, 16, steps)).unwrap();
    let run4 = train(&mut rt, &cfg("vgg_tiny", 4, 16, steps)).unwrap();
    for (a, b) in [(&run1, &run2), (&run1, &run4)] {
        for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
            let d = (ra.loss - rb.loss).abs();
            // identical samples + deterministic reduce order; the only
            // divergence is fp reassociation across worker accumulators
            assert!(d < 5e-3 * ra.loss.abs().max(1.0), "step {}: {} vs {}", ra.step, ra.loss, rb.loss);
        }
        // final params drift only by accumulated rounding
        let max_d = a
            .final_params
            .iter()
            .flatten()
            .zip(b.final_params.iter().flatten())
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_d < 2e-2, "param drift {max_d}");
    }
}

#[test]
fn vgg_tiny_loss_decreases() {
    let Some(mut rt) = runtime() else { return };
    let out = train(&mut rt, &cfg("vgg_tiny", 2, 16, 30)).unwrap();
    let first = out.history.records[0].loss;
    let tail = out.history.tail_loss(5).unwrap();
    assert!(tail < first - 0.2, "loss {first} -> {tail}");
}

#[test]
fn cddnn_tiny_loss_decreases() {
    let Some(mut rt) = runtime() else { return };
    let mut c = cfg("cddnn_tiny", 2, 128, 15);
    c.lr = 0.05;
    let out = train(&mut rt, &c).unwrap();
    let first = out.history.records[0].loss;
    let tail = out.history.tail_loss(3).unwrap();
    assert!(tail < first - 0.1, "loss {first} -> {tail}");
}

#[test]
fn gpt_test_loss_decreases_toward_corpus_floor() {
    let Some(mut rt) = runtime() else { return };
    let mut c = cfg("gpt_test", 1, 32, 60);
    c.lr = 0.01;
    c.optimizer = "adam".into();
    let out = train(&mut rt, &c).unwrap();
    let first = out.history.records[0].loss;
    let tail = out.history.tail_loss(5).unwrap();
    assert!(tail < first - 0.5, "loss {first} -> {tail}");
    // corpus floor for vocab=64 is ~1.7 nats; uniform is ln(64)=4.16
    assert!(first > 3.5, "init loss should be near ln(vocab): {first}");
}

#[test]
fn eval_artifact_reports_accuracy_improving() {
    let Some(mut rt) = runtime() else { return };
    let mut c = cfg("vgg_tiny", 1, 16, 90);
    c.eval_every = 30;
    let out = train(&mut rt, &c).unwrap();
    assert!(out.evals.len() >= 2);
    let first = out.evals.first().unwrap();
    let last = out.evals.last().unwrap();
    // top5 on held-out data should beat chance (0.5 for 10 classes)
    // after training on class-template data
    assert!(last.top5 >= first.top5 - 0.05, "top5 {} -> {}", first.top5, last.top5);
    assert!(last.top5 > 0.5, "top5 {}", last.top5);
}

#[test]
fn throughput_accounting_sane() {
    let Some(mut rt) = runtime() else { return };
    let out = train(&mut rt, &cfg("vgg_tiny", 2, 8, 5)).unwrap();
    for r in &out.history.records {
        assert!(r.images_per_s > 0.0);
        assert!(r.compute_s > 0.0);
        assert!(r.comm_wait_s >= 0.0);
        assert!(r.overlap_s >= 0.0);
        assert!(r.data_stall_us >= 0.0);
    }
}

#[test]
fn different_seeds_different_data() {
    let Some(mut rt) = runtime() else { return };
    let mut a = cfg("vgg_tiny", 1, 8, 3);
    let mut b = cfg("vgg_tiny", 1, 8, 3);
    a.seed = 1;
    b.seed = 2;
    let ra = train(&mut rt, &a).unwrap();
    let rb = train(&mut rt, &b).unwrap();
    let da: Vec<f64> = ra.history.records.iter().map(|r| r.loss).collect();
    let db: Vec<f64> = rb.history.records.iter().map(|r| r.loss).collect();
    assert_ne!(da, db);
}
