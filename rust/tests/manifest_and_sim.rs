//! Manifest parsing robustness (synthetic manifests incl. error paths)
//! and simulator determinism guarantees.

use std::io::Write;

use pcl_dnn::analytic::machine::Platform;
use pcl_dnn::models::zoo;
use pcl_dnn::netsim::cluster::{simulate_training, SimConfig};
use pcl_dnn::netsim::Engine;
use pcl_dnn::runtime::Manifest;

/// Unique scratch dir under the system temp dir (no tempfile crate).
fn scratch(tag: &str) -> std::path::PathBuf {
    let d = std::env::temp_dir().join(format!(
        "pcl_dnn_test_{tag}_{}",
        std::time::SystemTime::now().duration_since(std::time::UNIX_EPOCH).unwrap().as_nanos()
    ));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn write(dir: &std::path::Path, name: &str, content: &[u8]) {
    let mut f = std::fs::File::create(dir.join(name)).unwrap();
    f.write_all(content).unwrap();
}

const MINI_MANIFEST: &str = r#"{
 "version": 1,
 "artifacts": {
  "m_train": {"hlo": "m.hlo.txt", "kind": "train", "model": "m", "batch": 2,
              "n_params": 1,
              "inputs": [{"name": "w", "shape": [3], "dtype": "f32"},
                         {"name": "x", "shape": [2, 3], "dtype": "f32"}],
              "outputs": [{"name": "loss", "shape": [], "dtype": "f32"},
                          {"name": "gw", "shape": [3], "dtype": "f32"}]}
 },
 "models": {
  "m": {"params_file": "m.params.bin", "n_elements": 3,
        "params": [{"name": "w", "shape": [3]}], "config": {"type": "test"}}
 }
}"#;

#[test]
fn synthetic_manifest_roundtrip() {
    let dir = scratch("ok");
    write(&dir, "manifest.json", MINI_MANIFEST.as_bytes());
    let params: Vec<u8> =
        [1.0f32, 2.0, 3.0].iter().flat_map(|v| v.to_le_bytes()).collect();
    write(&dir, "m.params.bin", &params);
    let m = Manifest::load(&dir).unwrap();
    let a = m.artifact("m_train").unwrap();
    assert_eq!(a.batch, 2);
    assert_eq!(a.inputs[1].shape, vec![2, 3]);
    let p = m.load_params("m").unwrap();
    assert_eq!(p, vec![vec![1.0, 2.0, 3.0]]);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn manifest_missing_dir_is_helpful_error() {
    let err = Manifest::load("/nonexistent/definitely/missing").unwrap_err();
    assert!(format!("{err:#}").contains("make artifacts"), "{err:#}");
}

#[test]
fn manifest_rejects_wrong_params_size() {
    let dir = scratch("badsize");
    write(&dir, "manifest.json", MINI_MANIFEST.as_bytes());
    write(&dir, "m.params.bin", &[0u8; 8]); // 2 floats, spec says 3
    let m = Manifest::load(&dir).unwrap();
    assert!(m.load_params("m").is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn manifest_rejects_bad_version_and_garbage() {
    let dir = scratch("badver");
    write(&dir, "manifest.json", br#"{"version": 9, "artifacts": {}, "models": {}}"#);
    assert!(Manifest::load(&dir).is_err());
    write(&dir, "manifest.json", b"not json at all {{{");
    assert!(Manifest::load(&dir).is_err());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn unknown_artifact_lists_alternatives() {
    let dir = scratch("unknown");
    write(&dir, "manifest.json", MINI_MANIFEST.as_bytes());
    let m = Manifest::load(&dir).unwrap();
    let err = m.artifact("nope").unwrap_err();
    assert!(format!("{err}").contains("m_train"));
    std::fs::remove_dir_all(dir).ok();
}

// ------------------------- simulator determinism -------------------------

#[test]
fn engine_is_deterministic_across_runs() {
    let build = || {
        let mut e = Engine::new();
        let mut prev = None;
        for i in 0..50 {
            let deps: Vec<_> = prev.into_iter().collect();
            let id = e.add(&format!("t{i}"), i % 3, 7 + (i as u64 * 13) % 40, &deps);
            if i % 4 != 0 {
                prev = Some(id);
            } else {
                prev = None;
            }
        }
        e
    };
    let a = build().run();
    let b = build().run();
    assert_eq!(a.start_ns, b.start_ns);
    assert_eq!(a.end_ns, b.end_ns);
}

#[test]
fn simulation_results_are_reproducible() {
    let p = Platform::cori();
    let cfg = SimConfig::recipe(&zoo::vgg_a(), 64, 512);
    let a = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
    let b = simulate_training(&zoo::vgg_a(), &p, &cfg).unwrap();
    assert_eq!(a.iteration_s, b.iteration_s);
    assert_eq!(a.images_per_s, b.images_per_s);
}

#[test]
fn more_iterations_converge_to_steady_state() {
    // steady-state iteration time must not depend on how many warmup
    // iterations we simulate (within rounding)
    let p = Platform::cori();
    let short = simulate_training(
        &zoo::vgg_a(),
        &p,
        &SimConfig { iterations: 3, ..SimConfig::recipe(&zoo::vgg_a(), 32, 256) },
    )
    .unwrap();
    let long = simulate_training(
        &zoo::vgg_a(),
        &p,
        &SimConfig { iterations: 8, ..SimConfig::recipe(&zoo::vgg_a(), 32, 256) },
    )
    .unwrap();
    let rel = (short.iteration_s - long.iteration_s).abs() / long.iteration_s;
    assert!(rel < 0.01, "{} vs {}", short.iteration_s, long.iteration_s);
}

#[test]
fn overlap_matters_in_simulation() {
    // Disabling the §3.1 overlap structure (by simulating a degenerate
    // 1-iteration schedule) must never beat the steady state: warmup
    // iterations pay un-overlapped comm.
    let p = Platform::aws();
    let r = simulate_training(
        &zoo::overfeat_fast(),
        &p,
        &SimConfig { iterations: 4, ..SimConfig::recipe(&zoo::overfeat_fast(), 16, 256) },
    )
    .unwrap();
    // compute utilization must be meaningful and below 1 at 16 eth nodes
    assert!(r.compute_utilization > 0.3 && r.compute_utilization <= 1.0);
}
