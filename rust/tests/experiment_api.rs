//! Contract tests for the unified ExperimentSpec API:
//!
//! * the committed `specs/*.json` files equal the canonical in-code
//!   builders (so CLI aliases, benches and docs can never drift from
//!   the committed figures);
//! * running a spec parsed from disk produces a report bit-identical to
//!   running the builder spec — the deprecated CLI aliases call the
//!   builders and `repro run --spec` parses the files, so this IS the
//!   alias-equivalence guarantee;
//! * the serialized `ScalingReport` key set matches the pinned
//!   `specs/report_schema.txt` (CI schema-drift gate, testable offline);
//! * one spec runs on multiple backends via `Backend::run`.

use pcl_dnn::experiment::{
    backend_by_name, report::SCHEMA_KEYS, run_sweep, AnalyticBackend, Backend, ExperimentSpec,
    FleetSimBackend, ScalingReport,
};
use pcl_dnn::util::json::Json;

fn spec_path(file: &str) -> String {
    format!("{}/specs/{file}", env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn committed_specs_match_canonical_builders() {
    for (file, builder) in [
        ("fig4.json", ExperimentSpec::fig4()),
        ("fig6_overfeat.json", ExperimentSpec::fig6_overfeat()),
        ("fig6_vgg.json", ExperimentSpec::fig6_vgg()),
        ("fig7.json", ExperimentSpec::fig7()),
    ] {
        let from_file = ExperimentSpec::load(&spec_path(file)).unwrap();
        assert_eq!(from_file, builder, "specs/{file} drifted from ExperimentSpec builder");
    }
}

#[test]
fn cli_spec_run_is_bit_identical_to_the_alias_path() {
    // The deprecated aliases (`repro simulate fig7`) run the canonical
    // builders through Backend::run — exactly what this library call
    // does. The spec form is the REAL binary: `repro run --spec
    // specs/fig7.json --json`. Exec it and compare report bytes, so a
    // drifted hand-built spec anywhere in main.rs fails this test.
    let exe = env!("CARGO_BIN_EXE_repro");
    let out = std::process::Command::new(exe)
        .args(["run", "--spec", &spec_path("fig7.json"), "--backend", "analytic", "--json"])
        .output()
        .expect("repro binary executes");
    assert!(
        out.status.success(),
        "repro run failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).unwrap();
    let json_line = stdout
        .lines()
        .rev()
        .find(|l| l.starts_with('['))
        .expect("no JSON array line in CLI output");
    let alias_report = AnalyticBackend.run(&ExperimentSpec::fig7()).unwrap();
    assert_eq!(json_line, Json::Arr(vec![alias_report.to_json()]).to_string());
}

#[test]
fn committed_report_schema_matches_code() {
    let pinned = std::fs::read_to_string(spec_path("report_schema.txt")).unwrap();
    let pinned: Vec<&str> = pinned.lines().filter(|l| !l.is_empty()).collect();
    assert_eq!(
        pinned, SCHEMA_KEYS,
        "specs/report_schema.txt drifted from ScalingReport::SCHEMA_KEYS"
    );
}

#[test]
fn every_committed_spec_runs_on_the_analytic_backend() {
    // offline mirror of the CI `specs` job
    for file in ["fig4.json", "fig6_overfeat.json", "fig6_vgg.json", "fig7.json"] {
        let spec = ExperimentSpec::load(&spec_path(file)).unwrap();
        let report = AnalyticBackend.run(&spec).unwrap();
        let round = Json::parse(&report.to_json().to_string()).unwrap();
        ScalingReport::check_schema(&round).unwrap();
        let back = ScalingReport::from_json(&round).unwrap();
        assert_eq!(back.to_json().to_string(), report.to_json().to_string());
        assert!(report.samples_per_s > 0.0, "{file}");
    }
}

#[test]
fn one_spec_runs_on_analytic_and_netsim_backends() {
    // the acceptance shape: the SAME spec value through Backend::run on
    // different substrates, reports in the shared schema
    let mut spec = ExperimentSpec::load(&spec_path("fig4.json")).unwrap();
    spec.cluster.nodes = 8; // keep the per-message simulation test-sized
    spec.parallelism.iterations = 3;
    for name in ["analytic", "netsim"] {
        let r = backend_by_name(name).unwrap().run(&spec).unwrap();
        assert_eq!(r.backend, name);
        assert_eq!(r.nodes, 8);
        assert_eq!(r.minibatch, 512);
        assert_eq!(r.model, "vgg_a");
        ScalingReport::check_schema(&r.to_json()).unwrap();
    }
    // the runtime backend accepts the same spec; without AOT artifacts
    // (vendored xla stub) it must fail cleanly, not panic
    if let Err(e) = backend_by_name("runtime").unwrap().run(&spec) {
        let msg = format!("{e:#}");
        assert!(msg.contains("artifacts"), "unhelpful runtime error: {msg}");
    }
}

#[test]
fn collective_choice_is_honored_across_backends() {
    // pinning ring vs butterfly changes the schedule; `auto` must be no
    // slower than the better pinned choice (it picks per exchange)
    let mut spec = ExperimentSpec::fig6_overfeat();
    spec.cluster.nodes = 8;
    spec.parallelism.iterations = 3;
    let mut iters = std::collections::BTreeMap::new();
    for choice in ["auto", "ring", "butterfly"] {
        let mut s = spec.clone();
        s.collective = choice.into();
        iters.insert(choice, AnalyticBackend.run(&s).unwrap().iteration_s);
    }
    // 2% slack: auto shortens every comm task vs any pinned choice, but
    // a DAG makespan is not strictly monotone under greedy scheduling
    let best_pinned = iters["ring"].min(iters["butterfly"]);
    assert!(
        iters["auto"] <= best_pinned * 1.02,
        "auto {} vs best pinned {best_pinned}",
        iters["auto"]
    );
    // and the fleet backend accepts pinned algorithms too
    let mut s = spec.clone();
    s.collective = "ring".into();
    let ring = FleetSimBackend.run(&s).unwrap();
    s.collective = "butterfly".into();
    let bfly = FleetSimBackend.run(&s).unwrap();
    assert!(ring.tasks != bfly.tasks, "pinned algorithms built identical schedules");
}

#[test]
fn reports_record_the_partition_plan() {
    // every simulation backend records the plan it executed, in the
    // canonical PartitionPlan JSON (parse-able, node-count-correct)
    use pcl_dnn::plan::PartitionPlan;
    let mut spec = ExperimentSpec::load(&spec_path("fig7.json")).unwrap();
    spec.cluster.nodes = 8;
    spec.parallelism.iterations = 3;
    for name in ["analytic", "netsim"] {
        let r = backend_by_name(name).unwrap().run(&spec).unwrap();
        let plan = PartitionPlan::from_json(&r.plan).unwrap();
        assert_eq!(plan.nodes, 8, "{name}");
        assert_eq!(plan.minibatch, 1024, "{name}");
        // the CD-DNN FC stack must not be pure data parallel under the
        // default hybrid recipe
        assert!(!plan.is_pure_data(), "{name}");
    }
}

#[test]
fn recovery_policy_round_trips_and_is_settable() {
    // the new cluster.recovery field: full JSON round trip at every value
    for policy in ["stall", "replan", "shrink"] {
        let mut s = ExperimentSpec::fig4();
        s.cluster.recovery = policy.into();
        s.cluster.fail_at = Some(1);
        let back = ExperimentSpec::parse_str(&s.to_json().to_string()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.cluster.recovery, policy);
    }
    // --set coverage: dotted path and flat alias
    let mut s = ExperimentSpec::fig4();
    s.apply_set("cluster.recovery=replan").unwrap();
    assert_eq!(s.cluster.recovery, "replan");
    s.apply_set("recovery=shrink,fail_at=2").unwrap();
    assert_eq!(s.cluster.recovery, "shrink");
    assert_eq!(s.cluster.fail_at, Some(2));
    // an unknown policy fails listing the three valid ones — at parse
    // time AND through --set
    for bad in [
        ExperimentSpec::parse_str(r#"{"cluster": {"recovery": "failover"}}"#).unwrap_err(),
        ExperimentSpec::fig4().apply_set("cluster.recovery=reboot").unwrap_err(),
    ] {
        let msg = format!("{bad:#}");
        assert!(
            msg.contains("stall") && msg.contains("replan") && msg.contains("shrink"),
            "{msg}"
        );
    }
}

#[test]
fn committed_specs_still_parse_with_the_recovery_field() {
    // adding cluster.recovery must not disturb the committed figures:
    // they parse to the same spec values as before (default "stall"),
    // and re-serializing + re-parsing is bit-stable
    for file in ["fig4.json", "fig6_overfeat.json", "fig6_vgg.json", "fig7.json"] {
        let spec = ExperimentSpec::load(&spec_path(file)).unwrap();
        assert_eq!(spec.cluster.recovery, "stall", "{file}");
        assert_eq!(spec.cluster.fail_at, None, "{file}");
        let text = spec.to_json().to_string();
        let back = ExperimentSpec::parse_str(&text).unwrap();
        assert_eq!(back, spec, "{file}");
        assert_eq!(back.to_json().to_string(), text, "{file}");
    }
}

#[test]
fn auto_mode_runs_through_the_backend_api() {
    let mut spec = ExperimentSpec::load(&spec_path("fig4.json")).unwrap();
    spec.cluster.nodes = 8;
    spec.parallelism.iterations = 3;
    spec.parallelism.mode = "auto".into();
    let auto = AnalyticBackend.run(&spec).unwrap();
    spec.parallelism.mode = "data".into();
    let data = AnalyticBackend.run(&spec).unwrap();
    // the planner's never-worse guarantee, visible through the API
    assert!(auto.iteration_s <= data.iteration_s * (1.0 + 1e-9));
}

#[test]
fn sweep_over_committed_fig6_reproduces_paper_ordering() {
    // Fig 6's claim: VGG-A out-scales OverFeat on Ethernet
    let of = run_sweep(
        &AnalyticBackend,
        &ExperimentSpec::load(&spec_path("fig6_overfeat.json")).unwrap(),
        &[16],
    )
    .unwrap();
    let vg = run_sweep(
        &AnalyticBackend,
        &ExperimentSpec::load(&spec_path("fig6_vgg.json")).unwrap(),
        &[16],
    )
    .unwrap();
    assert!(vg[0].speedup.unwrap() > of[0].speedup.unwrap());
}

/// Full acceptance run: `specs/fig4.json` UNCHANGED (128 nodes) on all
/// three backends. The netsim point expands every collective of all 128
/// nodes to per-message tasks — it was `#[ignore]`d when the engine
/// rescanned the ready set per event; the indexed dispatch runs it in
/// the default suite.
#[test]
fn fig4_spec_runs_unchanged_on_all_three_backends() {
    let spec = ExperimentSpec::load(&spec_path("fig4.json")).unwrap();
    let a = AnalyticBackend.run(&spec).unwrap();
    assert!(a.speedup.unwrap() > 60.0);
    let f = FleetSimBackend.run(&spec).unwrap();
    assert!(f.samples_per_s > 0.0);
    match backend_by_name("runtime").unwrap().run(&spec) {
        Ok(r) => assert!(r.samples_per_s > 0.0),
        Err(e) => assert!(format!("{e:#}").contains("artifacts")),
    }
}
