//! Fault-injection + recovery suite (ISSUE 9).
//!
//! Drives the coordinator's fault seam (`step_with_compute_guarded`)
//! with synthetic deterministic gradients — no PJRT artifacts needed —
//! through the same recovery glue the trainer uses: an async
//! [`CheckpointWriter`], [`fault::recover`] under all three policies,
//! and replay. The central acceptance property: a worker death at a
//! configured step, recovered under `stall`, leaves losses and final
//! parameters **bit-identical** (f32 `to_bits`) to an uninterrupted
//! run — across worker counts x optimizers x both exchange pipelines.
//!
//! The final test (artifact-gated) runs the real runtime backend with a
//! live injected death and cross-checks its measured recovery section
//! against netsim's prediction in the shared report schema.

use std::cell::Cell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use pcl_dnn::checkpoint::CheckpointWriter;
use pcl_dnn::collectives::GroupTopology;
use pcl_dnn::coordinator::state::Optimizer;
use pcl_dnn::coordinator::{
    MicrobatchPlan, SgdConfig, StepResult, SyncSgdCoordinator,
};
use pcl_dnn::plan::PartitionPlan;
use pcl_dnn::trainer::fault::{self, RecoveryMeasurement, RecoveryPlanner};

// ---- deterministic synthetic gradients (overlap_tests idiom) --------

fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

fn grad_val(seed: u64, step: u64, w: u64, m: u64, t: u64, i: u64) -> f32 {
    let e = i.wrapping_mul(0x2545_f491_4f6c_dd1d);
    let h = mix(seed ^ mix(step ^ mix(w ^ mix(m ^ mix(t ^ e)))));
    (h >> 40) as f32 / (1u64 << 24) as f32 - 0.5
}

fn init_params(shapes: &[usize], seed: u64) -> Vec<Vec<f32>> {
    shapes
        .iter()
        .enumerate()
        .map(|(t, &n)| {
            (0..n).map(|i| 0.2 * grad_val(seed, 7, 7, 7, t as u64, i as u64)).collect()
        })
        .collect()
}

/// Synthetic worker compute that is a PURE function of (step, worker,
/// tensor, element) — the step index comes from an external cell the
/// training loop advances, so a replayed step recomputes the exact same
/// gradients an uninterrupted run saw. (The call-counter idiom of
/// overlap_tests cannot replay.)
fn make_compute(
    seed: u64,
    step_cell: Rc<Cell<u64>>,
) -> impl FnMut(usize, &[usize], &mut [Vec<f32>]) -> anyhow::Result<(f64, u64)> {
    move |w: usize, starts: &[usize], acc: &mut [Vec<f32>]| {
        let step = step_cell.get();
        let mut loss = 0.0f64;
        for (m, _start) in starts.iter().enumerate() {
            for (t, buf) in acc.iter_mut().enumerate() {
                for (i, x) in buf.iter_mut().enumerate() {
                    let g = grad_val(seed, step, w as u64, m as u64, t as u64, i as u64);
                    if m == 0 {
                        *x = g;
                    } else {
                        *x += g;
                    }
                }
            }
            loss += grad_val(seed ^ 0x1055, step, w as u64, m as u64, 0, u64::MAX) as f64;
        }
        Ok((loss.abs() + 0.1, starts.len() as u64))
    }
}

fn sgd_for(opt: &str) -> SgdConfig {
    match opt {
        "sgd" => {
            SgdConfig { lr: 0.05, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::Sgd }
        }
        "momentum" => {
            SgdConfig { lr: 0.05, momentum: 0.9, weight_decay: 5e-4, optimizer: Optimizer::Sgd }
        }
        "adam" => {
            SgdConfig { lr: 3e-3, momentum: 0.0, weight_decay: 0.0, optimizer: Optimizer::adam() }
        }
        other => panic!("unknown optimizer {other}"),
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("pcl-dnn-recovery-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    d
}

struct Fault {
    at_step: u64,
    worker: usize,
    policy: &'static str,
}

struct RunResult {
    /// loss bits indexed by step (replays overwrite in place, exactly
    /// like the committed trajectory they must reproduce)
    losses: Vec<u64>,
    param_bits: Vec<Vec<u32>>,
    recovery: Option<RecoveryMeasurement>,
}

/// The trainer's loop at the synthetic level: checkpoint every
/// `checkpoint_every` steps (0 = off), kill `fault.worker` at
/// `fault.at_step`, recover under `fault.policy`, run to `steps`.
fn run_training(
    shapes: &[usize],
    workers: usize,
    opt: &str,
    overlap: bool,
    steps: u64,
    fault: Option<Fault>,
    checkpoint_every: u64,
    dir: &Path,
    seed: u64,
) -> RunResult {
    let global_mb = workers * 4;
    let micro = 2usize;
    let plan = MicrobatchPlan::new(global_mb, workers, micro).unwrap();
    let mut coord = SyncSgdCoordinator::with_plan(
        "synthetic",
        init_params(shapes, seed),
        plan,
        sgd_for(opt),
        Vec::new(),
    );
    coord.set_overlap(overlap);

    let mut writer = (checkpoint_every > 0).then(|| CheckpointWriter::spawn(dir).unwrap());
    let planner = fault.as_ref().map(|f| RecoveryPlanner {
        policy: fault::policy_from_str(f.policy).unwrap(),
        checkpoint_dir: dir.to_path_buf(),
        initial: coord.params.snapshot(),
        plan_before: None,
        replan_to: None,
        micro,
        global_mb,
        artifact: "synthetic".into(),
    });
    let mut armed = fault;

    let step_cell = Rc::new(Cell::new(0u64));
    let mut compute = make_compute(seed, step_cell.clone());
    let mut losses = vec![0u64; steps as usize];
    let mut recovery: Option<RecoveryMeasurement> = None;
    let mut step = 0u64;
    while step < steps {
        step_cell.set(step);
        let kill = armed.as_ref().filter(|f| f.at_step == step).map(|f| f.worker);
        match coord.step_with_compute_guarded(&mut compute, kill).unwrap() {
            StepResult::Done(stats) => {
                losses[step as usize] = stats.loss.to_bits();
                if checkpoint_every > 0 && (step + 1) % checkpoint_every == 0 {
                    if let Some(w) = writer.as_mut() {
                        w.submit(coord.params.snapshot());
                    }
                }
                step += 1;
            }
            StepResult::Died { worker } => {
                let f = armed.take().expect("death without an armed fault");
                assert_eq!(worker, f.worker, "wrong worker died");
                assert_eq!(step, f.at_step, "death at the wrong step");
                let rp = planner.as_ref().unwrap();
                if let Some(w) = writer.as_ref() {
                    w.flush(std::time::Duration::from_secs(10)).unwrap();
                }
                let mut topos_for = |_: Option<&PartitionPlan>,
                                     _: usize|
                 -> Vec<Option<GroupTopology>> { Vec::new() };
                let (next, meas) =
                    fault::recover(coord, step, worker, 0.0, rp, &mut topos_for).unwrap();
                coord = next;
                step = meas.resume_step;
                recovery = Some(meas);
            }
        }
    }
    let param_bits = coord
        .params
        .tensors
        .iter()
        .map(|t| t.iter().map(|x| x.to_bits()).collect())
        .collect();
    if let Some(w) = writer.take() {
        w.shutdown();
    }
    RunResult { losses, param_bits, recovery }
}

fn assert_bit_identical(a: &RunResult, b: &RunResult, ctx: &str) {
    for (s, (la, lb)) in a.losses.iter().zip(&b.losses).enumerate() {
        assert_eq!(
            la,
            lb,
            "{ctx}: loss bits diverged at step {s} ({} vs {})",
            f64::from_bits(*la),
            f64::from_bits(*lb)
        );
    }
    assert_eq!(a.param_bits.len(), b.param_bits.len(), "{ctx}: tensor count");
    for (t, (ta, tb)) in a.param_bits.iter().zip(&b.param_bits).enumerate() {
        for (i, (xa, xb)) in ta.iter().zip(tb).enumerate() {
            assert_eq!(xa, xb, "{ctx}: tensor {t} elem {i} diverged");
        }
    }
}

/// The acceptance property: an injected worker death recovered under
/// `stall` (restore last checkpoint + replay) reproduces the
/// uninterrupted run bit-for-bit — losses AND final parameters —
/// across workers x optimizers, on both exchange pipelines.
#[test]
fn stall_recovery_is_bit_identical_to_uninterrupted_run() {
    let shapes = [129usize, 517, 33];
    let steps = 8u64;
    let mut seed = 0x9c0_u64;
    for workers in [2usize, 4, 8] {
        for opt in ["sgd", "momentum", "adam"] {
            seed = mix(seed);
            for overlap in [true, false] {
                let ctx = format!("workers={workers} opt={opt} overlap={overlap}");
                let dir = tmp_dir(&format!("stall-{workers}-{opt}-{overlap}"));
                let clean = run_training(
                    &shapes, workers, opt, overlap, steps, None, 0, &dir, seed,
                );
                assert!(clean.recovery.is_none());
                // kill the last worker at step 5 with checkpoints every
                // 2 steps: restores step 4's checkpoint, replays 4
                let faulted = run_training(
                    &shapes,
                    workers,
                    opt,
                    overlap,
                    steps,
                    Some(Fault { at_step: 5, worker: workers - 1, policy: "stall" }),
                    2,
                    &dir,
                    seed,
                );
                let meas = faulted.recovery.as_ref().expect("fault never fired");
                assert_eq!(meas.resume_step, 4, "{ctx}");
                assert_eq!(meas.replay_steps, 1, "{ctx}");
                assert_eq!(meas.workers_after, workers, "{ctx}");
                assert!(meas.restore_s >= 0.0 && meas.stall_s() >= 0.0, "{ctx}");
                assert_bit_identical(&clean, &faulted, &ctx);
                let _ = std::fs::remove_dir_all(&dir);
            }
        }
    }
}

/// Stall with NO checkpoint on disk falls back to the step-0 snapshot
/// and replays the whole prefix — still bit-identical.
#[test]
fn stall_without_checkpoints_replays_from_step_zero() {
    let shapes = [257usize, 65];
    let dir = tmp_dir("stall-nockpt");
    let clean = run_training(&shapes, 4, "momentum", true, 6, None, 0, &dir, 0xfee1);
    let faulted = run_training(
        &shapes,
        4,
        "momentum",
        true,
        6,
        Some(Fault { at_step: 3, worker: 0, policy: "stall" }),
        0, // checkpointing off entirely
        &dir,
        0xfee1,
    );
    let meas = faulted.recovery.as_ref().unwrap();
    assert_eq!(meas.resume_step, 0, "no checkpoint => restart from scratch");
    assert_eq!(meas.replay_steps, 3);
    assert_bit_identical(&clean, &faulted, "stall-nockpt");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `shrink` and `replan` continue at N-1 survivors: the run completes,
/// the measurement reflects the degraded fleet, and the survivors keep
/// the pre-failure state (the failed step never committed, so the first
/// post-recovery step starts from exactly the step-N-1 parameters).
#[test]
fn shrink_and_replan_continue_at_n_minus_one() {
    let shapes = [129usize, 513];
    for policy in ["shrink", "replan"] {
        for workers in [2usize, 4, 8] {
            let ctx = format!("policy={policy} workers={workers}");
            let dir = tmp_dir(&format!("{policy}-{workers}"));
            let faulted = run_training(
                &shapes,
                workers,
                "momentum",
                true,
                7,
                Some(Fault { at_step: 3, worker: 0, policy }),
                2,
                &dir,
                0xd00d,
            );
            let meas = faulted.recovery.as_ref().unwrap_or_else(|| panic!("{ctx}: no fault"));
            assert_eq!(meas.workers_before, workers, "{ctx}");
            assert_eq!(meas.workers_after, workers - 1, "{ctx}");
            // no rollback: the failed step is re-run on the survivors
            assert_eq!(meas.resume_step, 3, "{ctx}");
            assert_eq!(meas.replay_steps, 0, "{ctx}");
            assert!(meas.restore_s == 0.0, "{ctx}: shrink/replan never restore");
            assert!(meas.redistribution_s >= 0.0, "{ctx}");
            // every step has a committed loss (none skipped or doubled)
            assert!(faulted.losses.iter().all(|&l| l != 0), "{ctx}: missing step loss");
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}

/// The degraded-minibatch respread under a plan: renormalize_for + the
/// trainer's uneven respread compose for a hybrid plan (the shapes the
/// runtime recovery actually rebuilds with).
#[test]
fn respread_composes_with_renormalized_plans() {
    // MB 16 over 4 workers, micro 2 -> 3 survivors: the minibatch (a
    // hyperparameter) stays 16; the 8 microbatches go 3/3/2
    let r = fault::respread(16, 3, 2).unwrap();
    assert_eq!((r.plan.global_mb, r.plan.workers, r.plan.micro), (16, 3, 2));
    assert_eq!(r.residual_mb, 0);
    assert_eq!(r.plan.per_worker.len(), 3);
    let counts: Vec<usize> = r.plan.per_worker.iter().map(Vec::len).collect();
    assert_eq!(counts, vec![3, 3, 2]);
    // already-divisible minibatches survive untouched and uniform
    let r = fault::respread(24, 3, 2).unwrap();
    assert_eq!(r.plan.global_mb, 24);
    assert!(r.plan.per_worker.iter().all(|w| w.len() == 4));
    // a 2-worker fleet losing a node still trains (1 survivor)
    let r = fault::respread(8, 1, 2).unwrap();
    assert_eq!((r.plan.global_mb, r.plan.workers), (8, 1));
}

/// Recovered coordinators keep working for many more steps (no leaked
/// comm-thread state, no poisoned pools) — run a long tail after a
/// shrink and after a stall back to back.
#[test]
fn recovered_coordinator_survives_a_long_tail() {
    let shapes = [1031usize];
    for policy in ["stall", "shrink"] {
        let dir = tmp_dir(&format!("tail-{policy}"));
        let out = run_training(
            &shapes,
            4,
            "adam",
            true,
            20,
            Some(Fault { at_step: 2, worker: 1, policy }),
            3,
            &dir,
            0xcafe,
        );
        assert!(out.recovery.is_some(), "{policy}: fault never fired");
        assert!(out.losses.iter().all(|&l| l != 0), "{policy}: missing step loss");
        let _ = std::fs::remove_dir_all(&dir);
    }
}

// ---- the real thing: runtime backend + artifacts (gated) ------------

/// Live injected death through the PJRT trainer: the runtime backend
/// emits a non-null measured recovery section that cross-checks
/// netsim's prediction of the same spec in the shared schema.
#[test]
fn runtime_backend_recovery_cross_checks_netsim() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use pcl_dnn::experiment::{backend_by_name, run_runtime, ExperimentSpec, RecoveryReport};
    use pcl_dnn::util::json::Json;

    // stale checkpoints from an earlier run would carry a step past the
    // failure point; the suite owns this directory
    let _ = std::fs::remove_dir_all("artifacts/checkpoints");

    let mut spec = ExperimentSpec::default();
    spec.cluster.nodes = 2;
    spec.cluster.fail_at = Some(2);
    spec.cluster.fail_node = 1;
    spec.parallelism.iterations = 6;
    spec.minibatch.global = 16;
    spec.execution.steps = 8;
    spec.execution.log_every = 0;
    spec.execution.checkpoint = Some(2);

    for policy in ["stall", "shrink", "replan"] {
        spec.cluster.recovery = policy.into();
        let (rep, out) = run_runtime(&spec)
            .unwrap_or_else(|e| panic!("runtime run failed under {policy}: {e:#}"));
        assert!(
            !matches!(rep.recovery, Json::Null),
            "{policy}: runtime report recovery section is null"
        );
        let rec = RecoveryReport::from_json(&rep.recovery).unwrap();
        assert_eq!(rec.policy, policy);
        assert_eq!(rec.fail_at, 2, "{policy}");
        assert_eq!(rec.fail_node, 1, "{policy}");
        assert_eq!(rec.nodes_before, 2, "{policy}");
        assert_eq!(rec.nodes_after, if policy == "stall" { 2 } else { 1 }, "{policy}");
        assert!(rec.stall_s >= 0.0 && rec.stall_s.is_finite(), "{policy}: {}", rec.stall_s);
        assert!(rec.post_samples_per_s > 0.0, "{policy}");
        let meas = out.recovery.expect("outcome recovery");
        assert_eq!(meas.workers_after as u64, rec.nodes_after);

        // netsim prices the same spec in the same schema — the numbers
        // differ (simulated fabric vs shared-memory host), the shape and
        // policy semantics must not
        let net = backend_by_name("netsim").unwrap().run(&spec).unwrap();
        let nrec = RecoveryReport::from_json(&net.recovery)
            .unwrap_or_else(|e| panic!("netsim recovery section: {e:#}"));
        assert_eq!(nrec.policy, rec.policy);
        assert_eq!(nrec.nodes_after, rec.nodes_after, "{policy}");
        assert!(nrec.post_efficiency > 0.0, "{policy}");
        // both ends of the cross-check express post-failure efficiency
        // on the same scale (a fraction of ideal, not a throughput)
        assert!(rec.post_efficiency > 0.0 && rec.post_efficiency < 3.0, "{policy}: {}", rec.post_efficiency);
    }
    let _ = std::fs::remove_dir_all("artifacts/checkpoints");
}

/// The trainer rejects fault configs that cannot produce a measurable
/// recovery instead of silently ignoring them.
#[test]
fn trainer_validates_fault_configuration() {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use pcl_dnn::runtime::Runtime;
    use pcl_dnn::trainer::{train, TrainConfig};
    let mut rt = Runtime::new("artifacts").unwrap();
    let base = TrainConfig {
        model: "vgg_tiny".into(),
        workers: 2,
        global_mb: 16,
        steps: 4,
        log_every: 0,
        ..TrainConfig::default()
    };
    // fail_at too late to leave a post-recovery step
    let mut c = base.clone();
    c.fail_at = Some(3);
    assert!(train(&mut rt, &c).is_err());
    // dead worker out of range
    let mut c = base.clone();
    c.fail_at = Some(1);
    c.fail_worker = 2;
    assert!(train(&mut rt, &c).is_err());
    // shrink below one worker
    let mut c = base.clone();
    c.workers = 1;
    c.global_mb = 8;
    c.fail_at = Some(1);
    c.fail_worker = 0;
    c.recovery = "shrink".into();
    assert!(train(&mut rt, &c).is_err());
    // unknown policy
    let mut c = base;
    c.fail_at = Some(1);
    c.recovery = "reboot".into();
    assert!(train(&mut rt, &c).is_err());
}
