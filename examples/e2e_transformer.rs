//! End-to-end driver (system-prompt deliverable): train a transformer LM
//! with the full stack — AOT JAX/Pallas artifacts, PJRT runtime, the
//! synchronous-SGD coordinator with its lock-free comm queue, the
//! dedicated data thread — for a few hundred steps on the synthetic
//! Markov corpus, logging the loss curve to CSV. The run is described by
//! an `ExperimentSpec` and executed through the runtime backend.
//!
//! Default model is gpt_mini (~11.4M params — sized for this 1-core CPU
//! image; see EXPERIMENTS.md). With `make artifacts-large` and
//! `--model gpt_large` the same driver trains the ~88M-param config.
//!
//! ```bash
//! cargo run --release --example e2e_transformer -- --steps 300 --workers 2
//! ```

use pcl_dnn::data::Corpus;
use pcl_dnn::experiment::{
    run_runtime_with, ExecutionSpec, ExperimentSpec, MinibatchSpec, ModelSpec,
};
use pcl_dnn::runtime::Runtime;
use pcl_dnn::trainer::evaluate;
use pcl_dnn::util::cli::Opts;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let model = opts.str_or("model", "gpt_mini");
    let steps: u64 = opts.parse_or("steps", 300u64)?;
    let workers: usize = opts.parse_or("workers", 2usize)?;
    let csv = opts.str_or("csv", "e2e_transformer_loss.csv");

    // inspect the manifest for the model's shape before building the spec
    let mut rt = Runtime::new("artifacts")?;
    let spec_meta = rt.manifest().model(&model)?;
    let vocab = spec_meta.config.get("vocab").unwrap().as_usize()?;
    let seq = spec_meta.config.get("seq").unwrap().as_usize()?;
    let n_elems = spec_meta.n_elements;
    let micro = rt.manifest().artifact(&format!("{model}_train"))?.batch;
    let global_mb = workers * micro * 2;
    println!(
        "e2e: {model} ({:.1}M params, vocab {vocab}, seq {seq}) — {steps} steps, {workers} workers, MB={global_mb}",
        n_elems as f64 / 1e6
    );
    let floor = Corpus::new(vocab, 0).entropy_floor();
    println!("corpus: synthetic Markov language, entropy floor {floor:.3} nats (uniform = {:.3})\n", (vocab as f64).ln());

    let spec = ExperimentSpec {
        name: "e2e_transformer".into(),
        model: ModelSpec::Zoo(model.clone()),
        minibatch: MinibatchSpec { global: global_mb as u64 },
        execution: ExecutionSpec {
            workers: Some(workers),
            steps,
            lr: opts.parse_or("lr", 2e-3f64)?,
            momentum: 0.0,
            seed: 0,
            log_every: (steps / 20).max(1),
            eval_every: (steps / 6).max(1),
            optimizer: opts.str_or("optimizer", "adam"),
            ..Default::default()
        },
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    // reuse the Runtime already holding the manifest (and, with a real
    // xla binding, the compiled executables) for both train and eval
    let (report, out) = run_runtime_with(&mut rt, &spec)?;
    let wall = t0.elapsed().as_secs_f64();

    out.history.save_csv(&csv)?;
    let first = out.history.records.first().unwrap().loss;
    let last5 = out.history.tail_loss(5).unwrap();
    let toks = steps as f64 * global_mb as f64 * seq as f64;
    println!("\n==== e2e summary ====");
    println!("loss: {first:.3} -> {last5:.3}  (corpus floor {floor:.3}, uniform {:.3})", (vocab as f64).ln());
    if let Some(e) = evaluate(&mut rt, &model, &out.final_params, 0)? {
        println!("held-out loss: {:.3}", e.loss);
    }
    println!(
        "wall: {wall:.1}s  |  {:.0} tokens/s  |  mean {:.1} sequences/s  |  compute {:.0}% of busy time",
        toks / wall,
        report.samples_per_s,
        100.0 * report.mean_compute_utilization
    );
    println!("loss curve: {csv}");
    anyhow::ensure!(last5 < first - 0.5, "LM failed to learn");
    println!("e2e OK");
    Ok(())
}
