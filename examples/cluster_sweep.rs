//! Regenerates every analytic table and simulated figure of the paper in
//! one run (Table 1, §2.2, §2.4, §3.3, Fig 3 model, Figs 4/6/7 curves) —
//! the programmatic companion to `repro analyze ...` / `repro run --spec
//! ...`, used to fill EXPERIMENTS.md. All scaling figures and
//! full-cluster scenarios go through the spec-driven experiment API;
//! their reports are written to `BENCH_cluster_sweep.json` in the shared
//! `ScalingReport` schema.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use std::collections::BTreeMap;

use pcl_dnn::analytic::machine::{MachineSpec, Platform};
use pcl_dnn::analytic::{cache_blocking, comm_model, compute_model, register_blocking, scaling};
use pcl_dnn::experiment::{
    curve_table, run_sweep, AnalyticBackend, Backend, ExperimentSpec, FleetSimBackend,
    ScalingReport,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::models::Layer;
use pcl_dnn::util::json::Json;

fn reports_json(reports: &[ScalingReport]) -> Json {
    Json::Arr(reports.iter().map(|r| r.to_json()).collect())
}

fn main() {
    // ---------------- Table 1 ----------------
    println!("## Table 1 — theoretical scaling of data parallelism");
    println!("(paper: 1336/336 FLOPs per byte; OverFeat 3 (86) / 2 (128); VGG-A 1 (256) / 1 (256))");
    let platforms =
        [("Ethernet", Platform::table1_ethernet()), ("FDR", Platform::table1_fdr())];
    let mut t = Table::new(&["", "2s9c+10GbE", "2s16c+FDR"]);
    t.row(vec![
        "comp-to-comms".into(),
        format!("{:.0}", platforms[0].1.comp_to_comms()),
        format!("{:.0}", platforms[1].1.comp_to_comms()),
    ]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        let c: Vec<String> = platforms
            .iter()
            .map(|(_, p)| {
                let (mb, n) = scaling::table1_row(&net, p, 256);
                format!("{mb} ({n})")
            })
            .collect();
        t.row(vec![net.name.clone(), c[0].clone(), c[1].clone()]);
    }
    t.print();

    // ---------------- §2.2 ----------------
    println!("\n## §2.2 — cache-blocking search, OverFeat-FAST C5, 128 KB");
    let c5 = zoo::overfeat_c5_paper();
    println!(
        "row-streaming B/F = {:.2} (paper 0.54); full-cache B/F(mb=8) = {:.4} (paper ~0.003)",
        compute_model::bf_ratio_row(&c5).unwrap(),
        compute_model::bf_ratio_full(&c5, 8).unwrap()
    );
    let b = cache_blocking::search(&c5, &cache_blocking::SearchCfg::default()).unwrap();
    println!(
        "best blocking under 128 KB: B/F {:.4} (paper bound <= 0.04), tile ({},{},{},{},{},{},{}), {} bytes",
        b.bf, b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b, b.kh_b, b.kw_b, b.bytes
    );

    // ---------------- §2.4 ----------------
    println!("\n## §2.4 — register blocking");
    let m = register_blocking::cycle_model(12, 8, 3);
    println!(
        "fwd C5 (RB=1x12, SW=8): efficiency {:.1}% (paper 88%); wt-grad 3x3 naive {:.0}% (paper 75%)",
        100.0 * m.efficiency,
        100.0 * register_blocking::weight_grad_naive_efficiency(3)
    );

    // ---------------- §3.3 ----------------
    println!("\n## §3.3 — hybrid parallelism optimum (FC 4096x4096, MB=256, N=64)");
    let fc = Layer::fc("fc", 4096, 4096);
    println!(
        "G* (continuous) = {:.2}; discrete best: overlap=0 -> G={}, overlap=1 -> G={}",
        comm_model::optimal_groups_continuous(4096, 256, 64),
        comm_model::optimal_groups(&fc, 256, 64, 0.0),
        comm_model::optimal_groups(&fc, 256, 64, 1.0),
    );

    // ---------------- Fig 3 ----------------
    println!("\n## Fig 3 — single-node model (E5-2698v3; paper: OF 315/90, VGG 95/30)");
    let mach = MachineSpec::e5_2698v3();
    let mut t = Table::new(&["net", "mode", "MB16", "MB32", "MB64", "MB128", "MB256"]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        for (mode, tr) in [("FP", false), ("FP+BP", true)] {
            let mut row = vec![net.name.clone(), mode.into()];
            row.extend(
                compute_model::fig3_row(&net, &mach, tr).iter().map(|(_, v)| format!("{v:.0}")),
            );
            t.row(row);
        }
    }
    t.print();

    // ---------------- Figs 4 / 6 / 7 (spec-driven) ----------------
    let mut bench_curves: BTreeMap<String, Json> = BTreeMap::new();
    let mut fig4_mb256 = ExperimentSpec::fig4();
    fig4_mb256.minibatch.global = 256;
    for (title, spec, nodes, expect) in [
        (
            "Fig 4 — VGG-A on Cori, MB=512",
            ExperimentSpec::fig4(),
            vec![1u64, 2, 4, 8, 16, 32, 64, 128],
            "paper: 90x @128, 2510 img/s",
        ),
        (
            "Fig 4 — VGG-A on Cori, MB=256",
            fig4_mb256,
            vec![1, 2, 4, 8, 16, 32, 64],
            "paper: 82% efficiency @64",
        ),
        (
            "Fig 6 — OverFeat on AWS, MB=256",
            ExperimentSpec::fig6_overfeat(),
            vec![1, 2, 4, 8, 16],
            "paper: 1027 img/s = 11.9x @16",
        ),
        (
            "Fig 6 — VGG-A on AWS, MB=256",
            ExperimentSpec::fig6_vgg(),
            vec![1, 2, 4, 8, 16],
            "paper: 397 img/s = 14.2x @16",
        ),
        (
            "Fig 7 — CD-DNN on Endeavor, MB=1024",
            ExperimentSpec::fig7(),
            vec![1, 2, 4, 8, 16],
            "paper: 4600 f/s @1, 29.5K = 6.4x @16",
        ),
    ] {
        println!("\n## {title}  ({expect})");
        let curve = run_sweep(&AnalyticBackend, &spec, &nodes).unwrap();
        curve_table(&curve).print();
        bench_curves.insert(title.to_string(), reports_json(&curve));
    }

    // ---------------- ablation: hybrid off ----------------
    println!("\n## Ablation — CD-DNN @16 nodes, hybrid FCs vs pure data parallel");
    let fig7 = ExperimentSpec::fig7();
    let mut fig7_data = fig7.clone();
    fig7_data.parallelism.mode = "data".into();
    let hy = AnalyticBackend.run(&fig7).unwrap().speedup.unwrap();
    let dp = AnalyticBackend.run(&fig7_data).unwrap().speedup.unwrap();
    println!("hybrid {hy:.1}x vs pure-data {dp:.1}x  (the §3.3 claim: hybrid wins for FC nets)");

    // ---------------- full-cluster simulator (spec-driven) ----------------
    println!("\n## Full-cluster simulator — cross-backend validation + fleet scenarios");
    let mut full_section = BTreeMap::new();

    // validation: the SAME spec on both backends, clean fabric
    let mut clean8 = ExperimentSpec::fig4();
    clean8.name = "fig4_clean_x8".into();
    clean8.cluster.nodes = 8;
    clean8.cluster.congestion = Some(0.0);
    clean8.minibatch.global = 256;
    let rep = AnalyticBackend.run(&clean8).unwrap();
    let full = FleetSimBackend.run(&clean8).unwrap();
    let delta = (full.iteration_s - rep.iteration_s) / rep.iteration_s;
    println!(
        "validation (VGG-A x8, clean fabric): netsim {:.2} ms vs analytic {:.2} ms ({:+.2}%)",
        full.iteration_s * 1e3,
        rep.iteration_s * 1e3,
        100.0 * delta
    );
    full_section.insert(
        "validation_vgg8".to_string(),
        reports_json(&[full.clone(), rep.clone()]),
    );

    // straggler-skew sweep (VGG-A x8 on Cori)
    let mut t = Table::new(&["skew", "iter ms", "slowdown", "min util"]);
    let mut srows = Vec::new();
    let mut base_s = 0.0;
    for skew in [0.0, 0.1, 0.25, 0.5, 1.0] {
        let mut s = clean8.clone();
        // the swept parameter is recorded in the report's spec name so
        // BENCH rows stay distinguishable across the trajectory
        s.name = format!("straggler_skew_{skew}");
        s.cluster.straggler_skew = skew;
        let r = FleetSimBackend.run(&s).unwrap();
        if base_s == 0.0 {
            base_s = r.iteration_s;
        }
        t.row(vec![
            format!("{skew:.2}"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.2}x", r.iteration_s / base_s),
            format!("{:.0}%", 100.0 * r.min_compute_utilization),
        ]);
        srows.push(r.to_json());
    }
    println!("straggler sweep (VGG-A x8, Cori):");
    t.print();
    full_section.insert("straggler_sweep".to_string(), Json::Arr(srows));

    // oversubscribed-Ethernet contention sweep (CD-DNN hybrid x8 on AWS)
    let mut dnn8 = ExperimentSpec::fig7();
    dnn8.name = "fig7_contention_x8".into();
    dnn8.platform = "aws".into();
    dnn8.cluster.nodes = 8;
    dnn8.cluster.congestion = Some(0.0);
    let mut flat_spec = dnn8.clone();
    flat_spec.name = "contention_flat".into();
    flat_spec.cluster.topology = "flat".into();
    let flat = FleetSimBackend.run(&flat_spec).unwrap();
    let mut t = Table::new(&["core", "iter ms", "vs flat"]);
    t.row(vec![
        "flat switch".into(),
        format!("{:.2}", flat.iteration_s * 1e3),
        "1.00x".into(),
    ]);
    let mut crows = vec![flat.to_json()];
    for oversub in [1.0, 2.0, 4.0] {
        let mut s = dnn8.clone();
        s.name = format!("contention_fattree_oversub_{oversub}");
        s.cluster.topology = "fattree".into();
        s.cluster.radix = 4;
        s.cluster.oversub = oversub;
        let r = FleetSimBackend.run(&s).unwrap();
        t.row(vec![
            format!("fat-tree {oversub}:1"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.2}x", r.iteration_s / flat.iteration_s),
        ]);
        crows.push(r.to_json());
    }
    println!("contention sweep (CD-DNN hybrid x8, AWS 10GbE, leaf radix 4):");
    t.print();
    full_section.insert("contention_sweep".to_string(), Json::Arr(crows));

    // ---------------- JSON bench trajectory ----------------
    let mut root = BTreeMap::new();
    root.insert("curves".to_string(), Json::Obj(bench_curves));
    root.insert("full_cluster".to_string(), Json::Obj(full_section));
    let path = "BENCH_cluster_sweep.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
