//! Regenerates every analytic table and simulated figure of the paper in
//! one run (Table 1, §2.2, §2.4, §3.3, Fig 3 model, Figs 4/6/7 curves) —
//! the programmatic companion to `repro analyze ...` / `repro simulate
//! ...`, used to fill EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example cluster_sweep
//! ```

use std::collections::BTreeMap;

use pcl_dnn::analytic::machine::{MachineSpec, Platform};
use pcl_dnn::analytic::{cache_blocking, comm_model, compute_model, register_blocking, scaling};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::models::Layer;
use pcl_dnn::netsim::cluster::{
    scaling_curve, simulate_training, simulate_training_fleet, SimConfig,
};
use pcl_dnn::netsim::{FleetConfig, Topology};
use pcl_dnn::util::json::Json;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    // ---------------- Table 1 ----------------
    println!("## Table 1 — theoretical scaling of data parallelism");
    println!("(paper: 1336/336 FLOPs per byte; OverFeat 3 (86) / 2 (128); VGG-A 1 (256) / 1 (256))");
    let platforms =
        [("Ethernet", Platform::table1_ethernet()), ("FDR", Platform::table1_fdr())];
    let mut t = Table::new(&["", "2s9c+10GbE", "2s16c+FDR"]);
    t.row(vec![
        "comp-to-comms".into(),
        format!("{:.0}", platforms[0].1.comp_to_comms()),
        format!("{:.0}", platforms[1].1.comp_to_comms()),
    ]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        let c: Vec<String> = platforms
            .iter()
            .map(|(_, p)| {
                let (mb, n) = scaling::table1_row(&net, p, 256);
                format!("{mb} ({n})")
            })
            .collect();
        t.row(vec![net.name.clone(), c[0].clone(), c[1].clone()]);
    }
    t.print();

    // ---------------- §2.2 ----------------
    println!("\n## §2.2 — cache-blocking search, OverFeat-FAST C5, 128 KB");
    let c5 = zoo::overfeat_c5_paper();
    println!(
        "row-streaming B/F = {:.2} (paper 0.54); full-cache B/F(mb=8) = {:.4} (paper ~0.003)",
        compute_model::bf_ratio_row(&c5).unwrap(),
        compute_model::bf_ratio_full(&c5, 8).unwrap()
    );
    let b = cache_blocking::search(&c5, &cache_blocking::SearchCfg::default()).unwrap();
    println!(
        "best blocking under 128 KB: B/F {:.4} (paper bound <= 0.04), tile ({},{},{},{},{},{},{}), {} bytes",
        b.bf, b.mb_b, b.ofm_b, b.oh_b, b.ow_b, b.ifm_b, b.kh_b, b.kw_b, b.bytes
    );

    // ---------------- §2.4 ----------------
    println!("\n## §2.4 — register blocking");
    let m = register_blocking::cycle_model(12, 8, 3);
    println!(
        "fwd C5 (RB=1x12, SW=8): efficiency {:.1}% (paper 88%); wt-grad 3x3 naive {:.0}% (paper 75%)",
        100.0 * m.efficiency,
        100.0 * register_blocking::weight_grad_naive_efficiency(3)
    );

    // ---------------- §3.3 ----------------
    println!("\n## §3.3 — hybrid parallelism optimum (FC 4096x4096, MB=256, N=64)");
    let fc = Layer::fc("fc", 4096, 4096);
    println!(
        "G* (continuous) = {:.2}; discrete best: overlap=0 -> G={}, overlap=1 -> G={}",
        comm_model::optimal_groups_continuous(4096, 256, 64),
        comm_model::optimal_groups(&fc, 256, 64, 0.0),
        comm_model::optimal_groups(&fc, 256, 64, 1.0),
    );

    // ---------------- Fig 3 ----------------
    println!("\n## Fig 3 — single-node model (E5-2698v3; paper: OF 315/90, VGG 95/30)");
    let mach = MachineSpec::e5_2698v3();
    let mut t = Table::new(&["net", "mode", "MB16", "MB32", "MB64", "MB128", "MB256"]);
    for net in [zoo::overfeat_fast(), zoo::vgg_a()] {
        for (mode, tr) in [("FP", false), ("FP+BP", true)] {
            let mut row = vec![net.name.clone(), mode.into()];
            row.extend(
                compute_model::fig3_row(&net, &mach, tr).iter().map(|(_, v)| format!("{v:.0}")),
            );
            t.row(row);
        }
    }
    t.print();

    // ---------------- Figs 4 / 6 / 7 ----------------
    let mut bench_curves: BTreeMap<String, Json> = BTreeMap::new();
    for (title, net, platform, mb, nodes, expect) in [
        (
            "Fig 4 — VGG-A on Cori, MB=512",
            zoo::vgg_a(),
            Platform::cori(),
            512u64,
            vec![1u64, 2, 4, 8, 16, 32, 64, 128],
            "paper: 90x @128, 2510 img/s",
        ),
        (
            "Fig 4 — VGG-A on Cori, MB=256",
            zoo::vgg_a(),
            Platform::cori(),
            256,
            vec![1, 2, 4, 8, 16, 32, 64],
            "paper: 82% efficiency @64",
        ),
        (
            "Fig 6 — OverFeat on AWS, MB=256",
            zoo::overfeat_fast(),
            Platform::aws(),
            256,
            vec![1, 2, 4, 8, 16],
            "paper: 1027 img/s = 11.9x @16",
        ),
        (
            "Fig 6 — VGG-A on AWS, MB=256",
            zoo::vgg_a(),
            Platform::aws(),
            256,
            vec![1, 2, 4, 8, 16],
            "paper: 397 img/s = 14.2x @16",
        ),
        (
            "Fig 7 — CD-DNN on Endeavor, MB=1024",
            zoo::cddnn_full(),
            Platform::endeavor(),
            1024,
            vec![1, 2, 4, 8, 16],
            "paper: 4600 f/s @1, 29.5K = 6.4x @16",
        ),
    ] {
        println!("\n## {title}  ({expect})");
        let curve = scaling_curve(&net, &platform, mb, &nodes, true);
        let mut t = Table::new(&["nodes", "samples/s", "speedup", "efficiency"]);
        for p in &curve {
            t.row(vec![
                p.nodes.to_string(),
                format!("{:.0}", p.images_per_s),
                format!("{:.1}x", p.speedup),
                format!("{:.0}%", 100.0 * p.efficiency),
            ]);
        }
        t.print();
        let rows: Vec<Json> = curve
            .iter()
            .map(|p| {
                let mut m = BTreeMap::new();
                m.insert("nodes".to_string(), num(p.nodes as f64));
                m.insert("samples_per_s".to_string(), num(p.images_per_s));
                m.insert("speedup".to_string(), num(p.speedup));
                m.insert("efficiency".to_string(), num(p.efficiency));
                Json::Obj(m)
            })
            .collect();
        bench_curves.insert(title.to_string(), Json::Arr(rows));
    }

    // ---------------- ablation: hybrid off ----------------
    println!("\n## Ablation — CD-DNN @16 nodes, hybrid FCs vs pure data parallel");
    let p = Platform::endeavor();
    let hy = scaling_curve(&zoo::cddnn_full(), &p, 1024, &[16], true)[0].speedup;
    let dp = scaling_curve(&zoo::cddnn_full(), &p, 1024, &[16], false)[0].speedup;
    println!("hybrid {hy:.1}x vs pure-data {dp:.1}x  (the §3.3 claim: hybrid wins for FC nets)");

    // ---------------- full-cluster simulator ----------------
    println!("\n## Full-cluster simulator — α-β validation + fleet scenarios");
    let mut full_section = BTreeMap::new();

    // validation: homogeneous contention-free fabric vs analytic model
    let mut clean = Platform::cori();
    clean.fabric.congestion_per_doubling = 0.0;
    let cfg8 = SimConfig { nodes: 8, minibatch: 256, ..Default::default() };
    let rep = simulate_training(&zoo::vgg_a(), &clean, &cfg8);
    let full = simulate_training_fleet(&zoo::vgg_a(), &clean, &cfg8, &FleetConfig::homogeneous(8));
    let delta = (full.iteration_s - rep.iteration_s) / rep.iteration_s;
    println!(
        "validation (VGG-A x8, clean fabric): full {:.2} ms vs analytic {:.2} ms ({:+.2}%)",
        full.iteration_s * 1e3,
        rep.iteration_s * 1e3,
        100.0 * delta
    );
    let mut vmap = BTreeMap::new();
    vmap.insert("full_iter_s".to_string(), num(full.iteration_s));
    vmap.insert("analytic_iter_s".to_string(), num(rep.iteration_s));
    vmap.insert("rel_delta".to_string(), num(delta));
    full_section.insert("validation_vgg8".to_string(), Json::Obj(vmap));

    // straggler-skew sweep (VGG-A x8 on Cori)
    let mut t = Table::new(&["skew", "iter ms", "slowdown", "min util"]);
    let mut srows = Vec::new();
    let mut base_s = 0.0;
    for skew in [0.0, 0.1, 0.25, 0.5, 1.0] {
        let fc = FleetConfig { nodes: 8, straggler_skew: skew, ..Default::default() };
        let r = simulate_training_fleet(&zoo::vgg_a(), &clean, &cfg8, &fc);
        if base_s == 0.0 {
            base_s = r.iteration_s;
        }
        t.row(vec![
            format!("{skew:.2}"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.2}x", r.iteration_s / base_s),
            format!("{:.0}%", 100.0 * r.min_compute_utilization),
        ]);
        let mut m = BTreeMap::new();
        m.insert("skew".to_string(), num(skew));
        m.insert("iter_s".to_string(), num(r.iteration_s));
        m.insert("slowdown".to_string(), num(r.iteration_s / base_s));
        srows.push(Json::Obj(m));
    }
    println!("straggler sweep (VGG-A x8, Cori):");
    t.print();
    full_section.insert("straggler_sweep".to_string(), Json::Arr(srows));

    // oversubscribed-Ethernet contention sweep (CD-DNN hybrid x8 on AWS)
    let mut aws = Platform::aws();
    aws.fabric.congestion_per_doubling = 0.0;
    let cfg_dnn = SimConfig { nodes: 8, minibatch: 1024, ..Default::default() };
    let flat = simulate_training_fleet(
        &zoo::cddnn_full(),
        &aws,
        &cfg_dnn,
        &FleetConfig { nodes: 8, topology: Topology::FlatSwitch, ..Default::default() },
    );
    let mut t = Table::new(&["core", "iter ms", "vs flat"]);
    t.row(vec![
        "flat switch".into(),
        format!("{:.2}", flat.iteration_s * 1e3),
        "1.00x".into(),
    ]);
    let mut crows = Vec::new();
    for oversub in [1.0, 2.0, 4.0] {
        let fc = FleetConfig {
            nodes: 8,
            topology: Topology::FatTree { radix: 4, oversub },
            ..Default::default()
        };
        let r = simulate_training_fleet(&zoo::cddnn_full(), &aws, &cfg_dnn, &fc);
        t.row(vec![
            format!("fat-tree {oversub}:1"),
            format!("{:.2}", r.iteration_s * 1e3),
            format!("{:.2}x", r.iteration_s / flat.iteration_s),
        ]);
        let mut m = BTreeMap::new();
        m.insert("oversub".to_string(), num(oversub));
        m.insert("iter_s".to_string(), num(r.iteration_s));
        m.insert("vs_flat".to_string(), num(r.iteration_s / flat.iteration_s));
        crows.push(Json::Obj(m));
    }
    println!("contention sweep (CD-DNN hybrid x8, AWS 10GbE, leaf radix 4):");
    t.print();
    full_section.insert("contention_sweep".to_string(), Json::Arr(crows));

    // ---------------- JSON bench trajectory ----------------
    let mut root = BTreeMap::new();
    root.insert("curves".to_string(), Json::Obj(bench_curves));
    root.insert("full_cluster".to_string(), Json::Obj(full_section));
    let path = "BENCH_cluster_sweep.json";
    match std::fs::write(path, format!("{}\n", Json::Obj(root))) {
        Ok(()) => println!("\nwrote {path}"),
        Err(e) => eprintln!("\ncould not write {path}: {e}"),
    }
}
