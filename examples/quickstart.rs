//! Quickstart: load the AOT artifacts, inspect the platform, train a tiny
//! VGG for a handful of synchronous-SGD steps across 2 workers, then
//! measure scoring throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use pcl_dnn::runtime::Runtime;
use pcl_dnn::trainer::{score_throughput, train, TrainConfig};

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());
    println!(
        "artifacts: {} compiled computations, {} models\n",
        rt.manifest().artifacts.len(),
        rt.manifest().models.len()
    );

    // --- train: 20 synchronous steps, 2 workers, global minibatch 16 ---
    let cfg = TrainConfig {
        model: "vgg_tiny".into(),
        workers: 2,
        global_mb: 16,
        steps: 20,
        lr: 0.01,
        log_every: 5,
        eval_every: 10,
        ..Default::default()
    };
    let out = train(&mut rt, &cfg)?;
    println!(
        "\nloss {:.3} -> {:.3} over {} steps",
        out.history.records.first().map(|r| r.loss).unwrap_or(f64::NAN),
        out.history.final_loss().unwrap_or(f64::NAN),
        cfg.steps
    );

    // --- score: forward-only throughput (the Fig 3 'FP' path) ---
    let tput = score_throughput(&mut rt, "vgg_tiny", 10, 0)?;
    println!("scoring throughput: {tput:.0} images/s");
    println!("\nquickstart OK");
    Ok(())
}
