//! Quickstart: one declarative `ExperimentSpec`, three backends.
//!
//! The same spec — VGG-A, 16 Cori nodes, MB=256 — is priced by the
//! analytic balance equations, simulated per-message by the
//! full-cluster discrete-event engine, and (when `make artifacts` has
//! been run with a real `xla` binding) executed on the PJRT runtime.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pcl_dnn::experiment::{backend_by_name, Backend, ExperimentSpec};

fn main() -> anyhow::Result<()> {
    let spec = ExperimentSpec::parse_str(
        r#"{"name": "quickstart", "model": "vgg_a", "platform": "cori",
            "cluster": {"nodes": 16}, "minibatch": 256,
            "execution": {"workers": 2, "steps": 20}}"#,
    )?;
    for name in ["analytic", "netsim", "runtime"] {
        match backend_by_name(name)?.run(&spec) {
            Ok(r) => println!("{name:>8}: {}", r.to_json()),
            Err(e) => println!("{name:>8}: skipped ({e})"),
        }
    }
    Ok(())
}
