//! ASR scenario (paper §5.4): the CD-DNN acoustic model.
//!
//! 1. trains the runnable scaled CD-DNN (7 hidden FC layers, the paper's
//!    depth) on synthetic senone-labeled frames, for real, multi-worker —
//!    through the spec API's runtime backend;
//! 2. reproduces Fig 7's scaling curve for the full-size 7x2048 network
//!    on the simulated Endeavor cluster, including the hybrid-vs-data
//!    parallel ablation (FC nets are where hybrid parallelism matters) —
//!    the same `ExperimentSpec` as `specs/fig7.json`, analytic backend.
//!
//! ```bash
//! cargo run --release --example asr_cddnn -- --steps 60
//! ```

use pcl_dnn::analytic::comm_model;
use pcl_dnn::experiment::{
    run_runtime, run_sweep, AnalyticBackend, ExecutionSpec, ExperimentSpec, MinibatchSpec,
    ModelSpec,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::models::zoo;
use pcl_dnn::models::Layer;
use pcl_dnn::util::cli::Opts;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let steps: u64 = opts.parse_or("steps", 60u64)?;

    println!("=== real training: cddnn_tiny (7 hidden FC layers) ===");
    let train_spec = ExperimentSpec {
        name: "asr_cddnn_train".into(),
        model: ModelSpec::Zoo("cddnn_tiny".into()),
        minibatch: MinibatchSpec { global: 256 },
        execution: ExecutionSpec {
            workers: Some(2),
            steps,
            lr: 0.05,
            log_every: (steps / 6).max(1),
            ..Default::default()
        },
        ..Default::default()
    };
    let (report, out) = run_runtime(&train_spec)?;
    println!(
        "frames/s (real, this CPU): {:.0}; loss {:.3} -> {:.3}",
        report.samples_per_s,
        out.history.records.first().unwrap().loss,
        out.history.tail_loss(5).unwrap()
    );

    println!("\n=== Fig 7: full CD-DNN (429 -> 7x2048 -> 9304) on simulated Endeavor ===");
    println!("(paper: 4600 f/s @1 node, ~13K @4, 29.5K @16 = 6.4x)");
    let spec = ExperimentSpec::fig7();
    let mut ablation = spec.clone();
    ablation.parallelism.mode = "data".into();
    let nodes = [1u64, 2, 4, 8, 16];
    let hybrid = run_sweep(&AnalyticBackend, &spec, &nodes)?;
    let data = run_sweep(&AnalyticBackend, &ablation, &nodes)?;
    let mut t = Table::new(&["nodes", "hybrid f/s", "speedup", "pure-data f/s", "speedup"]);
    for (h, d) in hybrid.iter().zip(&data) {
        t.row(vec![
            h.nodes.to_string(),
            format!("{:.0}", h.samples_per_s),
            format!("{:.1}x", h.speedup.unwrap_or(f64::NAN)),
            format!("{:.0}", d.samples_per_s),
            format!("{:.1}x", d.speedup.unwrap_or(f64::NAN)),
        ]);
    }
    t.print();

    println!("\nper-layer strategy (paper §3.2: FC prefers model/hybrid when ofm > minibatch):");
    for l in zoo::cddnn_full().layers.iter() {
        let s = comm_model::best_strategy(l, 1024, 16, 1.0);
        println!("  {:<8} -> {:?}", l.name, s);
    }
    let fc = Layer::fc("h", 2048, 2048);
    println!(
        "\nG* for a 2048x2048 hidden layer at MB=1024, N=16: {:.2} (continuous), {} (discrete)",
        comm_model::optimal_groups_continuous(2048, 1024, 16),
        comm_model::optimal_groups(&fc, 1024, 16, 1.0)
    );
    Ok(())
}
