//! Fig 5 reproduction — convergence equivalence of distributed synchronous
//! SGD: "Since we parallelize SGD retaining its synchronous nature, and
//! there are no hyperparameter changes, the convergence of the distributed
//! algorithm is identical to the single node version."
//!
//! Trains tiny-VGG with 1, 2, 4 and 8 workers on the SAME global
//! minibatch stream — one `ExperimentSpec` with only `execution.workers`
//! varied, through the runtime backend — and overlays the loss / Top-1 /
//! Top-5 curves. The only permitted divergence is f32 reassociation
//! across worker gradient accumulators (the paper's curves "overlap"; so
//! must ours).
//!
//! ```bash
//! cargo run --release --example convergence_equivalence [-- --steps 60]
//! ```

use pcl_dnn::experiment::{
    run_runtime_with, ExecutionSpec, ExperimentSpec, MinibatchSpec, ModelSpec,
};
use pcl_dnn::metrics::Table;
use pcl_dnn::runtime::Runtime;
use pcl_dnn::util::cli::Opts;

fn main() -> anyhow::Result<()> {
    let opts = Opts::from_env()?;
    let steps: u64 = opts.parse_or("steps", 60u64)?;
    let mb: u64 = opts.parse_or("minibatch", 32u64)?;

    // one Runtime for all four runs: the compiled-executable cache is
    // shared, only the worker count varies
    let mut rt = Runtime::new("artifacts")?;
    let workers = [1usize, 2, 4, 8];
    let mut runs = Vec::new();
    for &w in &workers {
        println!("--- {w} worker(s) ---");
        let spec = ExperimentSpec {
            name: format!("fig5_w{w}"),
            model: ModelSpec::Zoo("vgg_tiny".into()),
            minibatch: MinibatchSpec { global: mb },
            execution: ExecutionSpec {
                workers: Some(w),
                steps,
                lr: 0.01,
                log_every: steps / 3,
                eval_every: steps / 3,
                ..Default::default()
            },
            ..Default::default()
        };
        let (_report, out) = run_runtime_with(&mut rt, &spec)?;
        runs.push((w, out));
    }

    println!("\n# Fig 5 — loss curves must overlay (same global minibatch stream)");
    let mut t = Table::new(&["step", "loss w=1", "loss w=2", "loss w=4", "loss w=8", "max dev"]);
    let stride = (steps / 12).max(1) as usize;
    for i in (0..steps as usize).step_by(stride) {
        let losses: Vec<f64> = runs.iter().map(|(_, r)| r.history.records[i].loss).collect();
        let dev = losses.iter().cloned().fold(f64::MIN, f64::max)
            - losses.iter().cloned().fold(f64::MAX, f64::min);
        let mut row = vec![i.to_string()];
        row.extend(losses.iter().map(|l| format!("{l:.4}")));
        row.push(format!("{dev:.2e}"));
        t.row(row);
    }
    t.print();

    // Quantify. Two regimes: (1) early steps must agree to fp noise —
    // the K-worker step computes the same averaged gradient up to
    // summation associativity; (2) later steps may drift visibly because
    // SGD is chaotic (fp reassociation differences amplify), exactly as
    // on the real cluster — the paper's Fig 5 shows *curve overlay*, not
    // bitwise equality. Note: worker counts whose accumulation order is
    // left-to-right identical to serial (e.g. 4 workers x 1 microbatch
    // here) track the 1-worker run EXACTLY.
    let base = &runs[0].1;
    let mut early: f64 = 0.0;
    let mut final_dev: f64 = 0.0;
    for (_, r) in &runs[1..] {
        for (a, b) in base.history.records.iter().zip(&r.history.records).take(10) {
            early = early.max((a.loss - b.loss).abs() / a.loss.abs().max(1.0));
        }
        let fa = base.history.tail_loss(5).unwrap();
        let fb = r.history.tail_loss(5).unwrap();
        final_dev = final_dev.max((fa - fb).abs() / fa.abs().max(1.0));
    }
    println!("\nworst relative loss deviation, steps 0-9 (must be fp-noise): {early:.2e}");
    println!("worst relative tail-loss deviation (chaotic drift bound):    {final_dev:.2e}");
    println!("held-out metrics at final step:");
    let mut t = Table::new(&["workers", "eval loss", "top1", "top5"]);
    for (w, r) in &runs {
        if let Some(e) = r.evals.last() {
            t.row(vec![
                w.to_string(),
                format!("{:.4}", e.loss),
                format!("{:.3}", e.top1),
                format!("{:.3}", e.top5),
            ]);
        }
    }
    t.print();
    anyhow::ensure!(early < 1e-3, "early curves diverged: {early}");
    anyhow::ensure!(final_dev < 0.30, "curves failed to overlay: {final_dev}");
    println!("\nconvergence equivalence holds (early deviation is fp-reassociation noise;");
    println!("late drift is chaotic amplification of that noise, same as on real clusters)");
    Ok(())
}
