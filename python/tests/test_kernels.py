"""L1 correctness: Pallas kernels vs pure-jnp oracles (the core signal).

Hypothesis sweeps shapes/strides/blockings; every case asserts allclose
against ref.py. Kernels run interpret=True (mandatory on CPU — see
kernels/conv2d.py docstring).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d as pconv
from compile.kernels import matmul as pmm
from compile.kernels import ref

TOL = dict(rtol=2e-5, atol=2e-5)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------- conv2d

@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    hw=st.integers(5, 14),
    cin=st.sampled_from([1, 3, 8, 13]),
    cout=st.sampled_from([1, 4, 16]),
    k=st.sampled_from([1, 3, 5]),
    stride=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_valid_matches_ref(n, hw, cin, cout, k, stride, seed):
    if hw < k:
        hw = k
    x = rand(seed, (n, hw, hw, cin))
    w = rand(seed + 1, (k, k, cin, cout))
    got = pconv.conv2d(x, w, stride=stride, padding="VALID")
    want = ref.conv2d_ref(x, w, stride=stride, padding="VALID")
    np.testing.assert_allclose(got, want, **TOL)


@settings(max_examples=15, deadline=None)
@given(
    hw=st.integers(4, 12),
    cin=st.sampled_from([2, 8]),
    cout=st.sampled_from([4, 8]),
    k=st.sampled_from([3, 5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_conv2d_same_matches_ref(hw, cin, cout, k, seed):
    x = rand(seed, (2, hw, hw, cin))
    w = rand(seed + 1, (k, k, cin, cout))
    got = pconv.conv2d(x, w, padding="SAME")
    want = ref.conv2d_ref(x, w, padding="SAME")
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("boh,boc,bic", [(1, 1, 1), (2, 4, 2), (4, 16, 8), (8, 16, 8)])
def test_conv2d_explicit_blockings_agree(boh, boc, bic):
    """Any legal blocking must produce identical results (paper §2.2:
    blocking changes the schedule, never the math)."""
    x = rand(7, (2, 10, 10, 8))
    w = rand(8, (3, 3, 8, 16))
    want = ref.conv2d_ref(x, w)
    got = pconv.conv2d(x, w, block_oh=boh, block_oc=boc, block_ic=bic)
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_1x1_kernel_is_pointwise_matmul():
    x = rand(1, (2, 6, 6, 8))
    w = rand(2, (1, 1, 8, 4))
    got = pconv.conv2d(x, w)
    want = jnp.einsum("nhwc,cd->nhwd", x, w[0, 0])
    np.testing.assert_allclose(got, want, **TOL)


def test_conv2d_rejects_channel_mismatch():
    with pytest.raises(AssertionError):
        pconv.conv2d(rand(0, (1, 5, 5, 4)), rand(1, (3, 3, 8, 4)))


def test_conv2d_linearity():
    """Convolution is linear in both arguments — a structural property the
    blocked accumulation must preserve exactly."""
    x1, x2 = rand(3, (1, 8, 8, 4)), rand(4, (1, 8, 8, 4))
    w = rand(5, (3, 3, 4, 8))
    lhs = pconv.conv2d(x1 + 2.0 * x2, w)
    rhs = pconv.conv2d(x1, w) + 2.0 * pconv.conv2d(x2, w)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------- choose_blocks

@settings(max_examples=40, deadline=None)
@given(
    oh=st.integers(1, 64),
    ow=st.integers(1, 64),
    cin=st.sampled_from([3, 16, 64, 256, 512]),
    cout=st.sampled_from([16, 64, 256, 1024]),
    k=st.sampled_from([1, 3, 5, 7, 11]),
)
def test_choose_blocks_invariants(oh, ow, cin, cout, k):
    boh, boc, bic = pconv.choose_blocks(oh, ow, cin, cout, k, k)
    assert oh % boh == 0 and cout % boc == 0 and cin % bic == 0
    assert 1 <= boh <= oh and 1 <= boc <= cout and 1 <= bic <= cin


def test_choose_blocks_respects_budget():
    """Selected tile must fit the stated VMEM budget (double-buffered),
    mirroring the paper's BS < Size_cache constraint."""
    oh, ow, cin, cout, k = 32, 32, 256, 512, 3
    boh, boc, _ = pconv.choose_blocks(oh, ow, cin, cout, k, k)
    bs = 4 * 2 * (boh * ow * boc + (boh + k - 1) * (ow + k - 1) * cin
                  + k * k * cin * boc)
    assert bs <= pconv.VMEM_BUDGET


# ---------------------------------------------------------------- matmul

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 64),
    k=st.integers(1, 96),
    n=st.integers(1, 64),
    relu=st.booleans(),
    with_bias=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(m, k, n, relu, with_bias, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    bias = rand(seed + 2, (n,)) if with_bias else None
    got = pmm.matmul(x, w, bias, relu)
    want = ref.matmul_ref(x, w, bias, relu)
    np.testing.assert_allclose(got, want, **TOL)


@pytest.mark.parametrize("bm,bn,bk", [(1, 1, 1), (8, 8, 16), (128, 128, 512)])
def test_matmul_blockings_agree(bm, bn, bk):
    x, w = rand(11, (32, 48)), rand(12, (48, 24))
    got = pmm.matmul(x, w, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul_ref(x, w), **TOL)


def test_matmul_relu_clamps_negatives():
    x = -jnp.ones((4, 4), jnp.float32)
    w = jnp.eye(4, dtype=jnp.float32)
    out = pmm.matmul(x, w, relu=True)
    assert (np.asarray(out) == 0.0).all()


# ------------------------------------------------------ grad-path oracles

def test_conv_backprop_and_wtgrad_consistent_with_autodiff():
    """The §2.1 claim: bprop and wt-grad are the same 7-loop with swapped
    operands. Check our two oracle entry points against jax autodiff."""
    x = rand(21, (2, 9, 9, 4))
    w = rand(22, (3, 3, 4, 8))
    y, vjp = jax.vjp(lambda a, b: ref.conv2d_ref(a, b), x, w)
    dy = rand(23, y.shape)
    dx, dw = vjp(dy)
    np.testing.assert_allclose(
        ref.conv2d_input_grad_ref(dy, w, x.shape), dx, **TOL)
    np.testing.assert_allclose(
        ref.conv2d_weight_grad_ref(x, dy, w.shape), dw, **TOL)
