"""L2 correctness: model zoo shapes, determinism, trainability, and the
pallas-vs-native forward equivalence that underpins the kernel ablation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.models import cddnn, cnn, common, transformer


def _data_cnn(cfg, n, seed=0):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, cfg.image, cfg.image, cfg.in_ch), jnp.float32)
    y = jax.random.randint(k, (n,), 0, cfg.classes, jnp.int32)
    return x, y


@pytest.mark.parametrize("cfg", [cnn.VGG_TINY, cnn.OVERFEAT_TINY])
def test_cnn_forward_shape(cfg):
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    x, _ = _data_cnn(cfg, 3)
    logits = cnn.forward(cfg, params, x)
    assert logits.shape == (3, cfg.classes)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("cfg", [cnn.VGG_TINY, cnn.OVERFEAT_TINY])
def test_cnn_param_specs_match_init(cfg):
    specs = cnn.param_specs(cfg)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    assert len(specs) == len(params)
    for (_, shape), p in zip(specs, params):
        assert tuple(shape) == p.shape


def test_cnn_pallas_forward_matches_native():
    cfg = cnn.VGG_TINY
    params = cnn.init_params(cfg, jax.random.PRNGKey(1))
    x, _ = _data_cnn(cfg, 2, seed=1)
    native = cnn.forward(cfg, params, x, use_pallas=False)
    pallas = cnn.forward(cfg, params, x, use_pallas=True)
    np.testing.assert_allclose(native, pallas, rtol=5e-5, atol=5e-5)


def test_cnn_train_step_decreases_loss():
    """A few SGD steps on a fixed batch must reduce the loss — the minimal
    trainability signal for the artifact the rust trainer executes."""
    cfg = cnn.VGG_TINY
    step = jax.jit(M.make_cnn_train_step(cfg))
    params = cnn.init_params(cfg, jax.random.PRNGKey(2))
    x, y = _data_cnn(cfg, 8, seed=2)
    first = None
    for _ in range(20):
        out = step(*params, x, y)
        loss, grads = out[0], out[1:]
        first = first if first is not None else float(loss)
        params = [p - 0.02 * g for p, g in zip(params, grads)]
    assert float(loss) < first - 0.05, (first, float(loss))


def test_cnn_train_step_is_deterministic():
    cfg = cnn.OVERFEAT_TINY
    step = jax.jit(M.make_cnn_train_step(cfg))
    params = cnn.init_params(cfg, jax.random.PRNGKey(3))
    x, y = _data_cnn(cfg, 4, seed=3)
    a = step(*params, x, y)
    b = step(*params, x, y)
    for u, v in zip(a, b):
        np.testing.assert_array_equal(np.asarray(u), np.asarray(v))


def test_cddnn_forward_and_train():
    cfg = cddnn.CDDNN_TINY
    params = cddnn.init_params(cfg, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(4)
    x = jax.random.normal(k, (16, cfg.in_dim), jnp.float32)
    y = jax.random.randint(k, (16,), 0, cfg.senones, jnp.int32)
    logits = cddnn.forward(cfg, params, x)
    assert logits.shape == (16, cfg.senones)
    step = jax.jit(M.make_cddnn_train_step(cfg))
    out = step(*params, x, y)
    assert len(out) == 1 + len(params)
    l0 = float(out[0])
    params2 = [p - 0.05 * g for p, g in zip(params, out[1:])]
    l1 = float(step(*params2, x, y)[0])
    assert l1 < l0


def test_cddnn_paper_config_dimensions():
    """Fig 7's network: 7 hidden x 2048, 429 in, 9304 senones."""
    cfg = cddnn.CDDNN_FULL
    specs = cddnn.param_specs(cfg)
    assert len(specs) == 2 * (7 + 1)
    assert specs[0][1] == (429, 2048)
    assert specs[-2][1] == (2048, 9304)


def test_gpt_forward_shape_and_causality():
    cfg = transformer.GPT_TEST
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(5)
    toks = jax.random.randint(k, (2, cfg.seq), 0, cfg.vocab, jnp.int32)
    logits = transformer.forward(cfg, params, toks)
    assert logits.shape == (2, cfg.seq, cfg.vocab)
    # causality: changing a future token must not change past logits
    toks2 = toks.at[:, -1].set((toks[:, -1] + 1) % cfg.vocab)
    logits2 = transformer.forward(cfg, params, toks2)
    np.testing.assert_allclose(logits[:, :-1], logits2[:, :-1], rtol=1e-5, atol=1e-5)


def test_gpt_train_step_decreases_loss():
    cfg = transformer.GPT_TEST
    step = jax.jit(M.make_gpt_train_step(cfg))
    params = transformer.init_params(cfg, jax.random.PRNGKey(1))
    toks = jnp.tile(jnp.arange(cfg.seq, dtype=jnp.int32) % 7, (4, 1))
    first = None
    for _ in range(15):
        out = step(*params, toks)
        loss, grads = out[0], out[1:]
        first = first if first is not None else float(loss)
        params = [p - 0.5 * g for p, g in zip(params, grads)]
    assert float(loss) < first - 0.3, (first, float(loss))


def test_gpt_param_count_formula():
    for cfg in [transformer.GPT_TEST, transformer.GPT_MINI, transformer.GPT_LARGE]:
        specs = transformer.param_specs(cfg)
        total = sum(int(np.prod(s)) for _, s in specs)
        assert total == cfg.n_params, (cfg.name, total, cfg.n_params)


def test_gpt_large_is_100m_class():
    assert transformer.GPT_LARGE.n_params >= 80_000_000


def test_sgd_apply_matches_host_update():
    n = 3
    apply = jax.jit(M.make_sgd_apply(n))
    ps = [jnp.ones((4,)) * i for i in range(n)]
    gs = [jnp.ones((4,)) * 0.5 for _ in range(n)]
    out = apply(*ps, *gs, jnp.float32(0.2))
    for i, o in enumerate(out):
        np.testing.assert_allclose(o, np.ones(4) * i - 0.1, rtol=1e-6)


def test_cross_entropy_and_topk():
    logits = jnp.array([[10.0, 0.0, 0.0], [0.0, 10.0, 0.0]], jnp.float32)
    labels = jnp.array([0, 1], jnp.int32)
    assert float(common.cross_entropy(logits, labels)) < 1e-3
    assert float(common.accuracy_topk(logits, labels, 1)) == 1.0
    wrong = jnp.array([1, 2], jnp.int32)
    assert float(common.accuracy_topk(logits, wrong, 1)) == 0.0
    assert float(common.accuracy_topk(logits, wrong, 3)) == 1.0
