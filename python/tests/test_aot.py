"""AOT pipeline: manifest/artifact consistency and HLO-text round-trip.

These tests run against the already-built ../artifacts directory (built by
`make artifacts`); they skip if it does not exist yet rather than re-lower
everything inside pytest.
"""

import json
import os
import struct

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_artifacts_exist_on_disk():
    m = _manifest()
    assert m["version"] == 1
    assert len(m["artifacts"]) >= 15
    for name, a in m["artifacts"].items():
        path = os.path.join(ART, a["hlo"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert text.startswith("HloModule"), f"{name} is not HLO text"
        assert "ENTRY" in text


def test_manifest_models_params_files_match_specs():
    m = _manifest()
    for name, model in m["models"].items():
        path = os.path.join(ART, model["params_file"])
        assert os.path.exists(path), name
        expect = sum(int(np.prod(p["shape"])) for p in model["params"])
        assert model["n_elements"] == expect
        assert os.path.getsize(path) == 4 * expect, name


def test_train_artifacts_have_loss_plus_grads_abi():
    """Train artifacts must return (loss, grad_i...) with grad shapes equal
    to param shapes in order — the ABI the rust coordinator assumes."""
    m = _manifest()
    for name, a in m["artifacts"].items():
        if a["kind"] != "train":
            continue
        model = m["models"][a["model"]]
        outs = a["outputs"]
        assert outs[0]["shape"] == []  # scalar loss
        grads = outs[1:]
        assert len(grads) == len(model["params"]), name
        for g, p in zip(grads, model["params"]):
            assert g["shape"] == p["shape"], (name, p["name"])


def test_params_bin_is_finite_f32():
    m = _manifest()
    model = m["models"]["vgg_tiny"]
    raw = open(os.path.join(ART, model["params_file"]), "rb").read()
    arr = np.frombuffer(raw, dtype="<f4")
    assert arr.size == model["n_elements"]
    assert np.isfinite(arr).all()
    # He-init weights are non-degenerate
    assert arr.std() > 1e-3


def test_inputs_start_with_params_in_spec_order():
    m = _manifest()
    for name, a in m["artifacts"].items():
        if not a.get("model") or a["kind"] == "sgd":
            continue
        model = m["models"][a["model"]]
        for inp, p in zip(a["inputs"], model["params"]):
            assert inp["shape"] == p["shape"], (name, p["name"])
            assert inp["dtype"] == "f32"


def test_hlo_text_reloads_through_xla_client():
    """Round-trip the smallest train artifact through the python XLA client
    (same HLO-text parser family the rust xla crate wraps)."""
    m = _manifest()
    import jax
    from jax._src.lib import xla_client as xc

    a = m["artifacts"]["matmul_native"]
    text = open(os.path.join(ART, a["hlo"])).read()
    # the HLO text parser lives behind the XlaComputation ctor
    comp = xc._xla.hlo_module_from_text(text)
    assert comp is not None
