"""AOT pipeline: lower every L2 step function to HLO *text* artifacts.

Runs ONCE at build time (`make artifacts`); python is never on the training
path. The interchange format is HLO text, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the rust `xla` crate) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs into --out-dir:
  <name>.hlo.txt          one per artifact (positional ABI)
  <model>.params.bin      little-endian f32 init params, spec order, seed 0
  manifest.json           full ABI description consumed by rust/src/runtime/
"""

import argparse
import json
import os
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .models import cddnn, cnn, transformer

SEED = 0


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32"):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.int32 if dtype == "i32" else jnp.float32)


def _io(name, shape, dtype="f32"):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class Builder:
    def __init__(self, out_dir: str):
        self.out_dir = out_dir
        self.manifest = {"version": 1, "artifacts": {}, "models": {}}
        os.makedirs(out_dir, exist_ok=True)

    def add_model(self, name: str, specs, config: dict):
        """Dump seed-0 init params for `specs` and register the model."""
        params = None
        key = jax.random.PRNGKey(SEED)
        from .models import common

        params = common.init_from_specs(specs, key)
        path = os.path.join(self.out_dir, f"{name}.params.bin")
        with open(path, "wb") as f:
            for p in params:
                f.write(np.asarray(p, dtype="<f4").tobytes())
        self.manifest["models"][name] = {
            "params_file": f"{name}.params.bin",
            "params": [{"name": n, "shape": list(s)} for n, s in specs],
            "n_elements": int(sum(int(np.prod(s)) for _, s in specs)),
            "config": config,
        }
        return params

    def add_artifact(self, name: str, fn, inputs: Sequence[dict], *, kind: str,
                     model: str = None, batch: int = 0, n_params: int = 0,
                     outputs=None):
        """Lower fn(*inputs) and write <name>.hlo.txt + manifest entry."""
        arg_specs = [_spec(i["shape"], i["dtype"]) for i in inputs]
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        if outputs is None:
            out_avals = jax.eval_shape(fn, *arg_specs)
            outputs = [
                _io(f"out{i}", o.shape, "i32" if o.dtype == jnp.int32 else "f32")
                for i, o in enumerate(out_avals)
            ]
        self.manifest["artifacts"][name] = {
            "hlo": fname,
            "kind": kind,
            "model": model,
            "batch": batch,
            "n_params": n_params,
            "inputs": list(inputs),
            "outputs": outputs,
        }
        print(f"  {name}: {len(text)} chars, {len(inputs)} inputs, {len(outputs)} outputs")

    def finish(self):
        with open(os.path.join(self.out_dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"manifest: {len(self.manifest['artifacts'])} artifacts, "
              f"{len(self.manifest['models'])} models")


def _param_ios(specs):
    return [_io(n, s) for n, s in specs]


def build_cnn(b: Builder, cfg: cnn.CnnConfig, train_b: int, fwd_b: int, eval_b: int):
    specs = cnn.param_specs(cfg)
    b.add_model(cfg.name, specs, {"type": "cnn", "image": cfg.image,
                                  "in_ch": cfg.in_ch, "classes": cfg.classes})
    pios = _param_ios(specs)
    img = lambda n: _io("images", (n, cfg.image, cfg.image, cfg.in_ch))
    lab = lambda n: _io("labels", (n,), "i32")
    b.add_artifact(f"{cfg.name}_train", M.make_cnn_train_step(cfg),
                   pios + [img(train_b), lab(train_b)], kind="train",
                   model=cfg.name, batch=train_b, n_params=len(specs))
    b.add_artifact(f"{cfg.name}_fwd", M.make_cnn_fwd(cfg),
                   pios + [img(fwd_b)], kind="fwd",
                   model=cfg.name, batch=fwd_b, n_params=len(specs))
    b.add_artifact(f"{cfg.name}_eval", M.make_cnn_eval(cfg),
                   pios + [img(eval_b), lab(eval_b)], kind="eval",
                   model=cfg.name, batch=eval_b, n_params=len(specs))


def build_cddnn(b: Builder, cfg: cddnn.CddnnConfig, train_b: int, fwd_b: int):
    specs = cddnn.param_specs(cfg)
    b.add_model(cfg.name, specs, {"type": "cddnn", "in_dim": cfg.in_dim,
                                  "hidden": cfg.hidden, "n_hidden": cfg.n_hidden,
                                  "senones": cfg.senones})
    pios = _param_ios(specs)
    b.add_artifact(f"{cfg.name}_train", M.make_cddnn_train_step(cfg),
                   pios + [_io("frames", (train_b, cfg.in_dim)),
                           _io("senones", (train_b,), "i32")],
                   kind="train", model=cfg.name, batch=train_b, n_params=len(specs))
    b.add_artifact(f"{cfg.name}_fwd", M.make_cddnn_fwd(cfg),
                   pios + [_io("frames", (fwd_b, cfg.in_dim))],
                   kind="fwd", model=cfg.name, batch=fwd_b, n_params=len(specs))


def build_gpt(b: Builder, cfg: transformer.GptConfig, train_b: int, eval_b: int):
    specs = transformer.param_specs(cfg)
    b.add_model(cfg.name, specs, {"type": "gpt", "vocab": cfg.vocab, "seq": cfg.seq,
                                  "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                                  "n_layers": cfg.n_layers,
                                  "n_params_total": cfg.n_params})
    pios = _param_ios(specs)
    tok = lambda n: _io("tokens", (n, cfg.seq), "i32")
    b.add_artifact(f"{cfg.name}_train", M.make_gpt_train_step(cfg),
                   pios + [tok(train_b)], kind="train", model=cfg.name,
                   batch=train_b, n_params=len(specs))
    b.add_artifact(f"{cfg.name}_eval", M.make_gpt_eval(cfg),
                   pios + [tok(eval_b)], kind="eval", model=cfg.name,
                   batch=eval_b, n_params=len(specs))


def build_kernel_ablation(b: Builder):
    """Same conv layer lowered via the Pallas kernel and via XLA's native
    conv — the L1 ablation pair (bench: pallas-interpret HLO vs cuDNN-style
    native lowering on the CPU PJRT backend)."""
    x_shape, w_shape = (8, 16, 16, 64), (3, 3, 64, 128)
    for tag, use_pallas in [("pallas", True), ("native", False)]:
        b.add_artifact(
            f"conv_layer_{tag}",
            M.make_conv_layer(x_shape, w_shape, 1, "SAME", use_pallas),
            [_io("x", x_shape), _io("w", w_shape)],
            kind="kernel", batch=x_shape[0],
        )
    # Pallas conv composed through a full scoring graph (fwd only: pallas
    # kernels are exercised under jit+vmap-style tracing, not autodiff).
    cfg = cnn.VGG_TINY
    specs = cnn.param_specs(cfg)
    b.add_artifact(
        "vgg_tiny_fwd_pallas",
        M.make_cnn_fwd(cfg, use_pallas=True),
        _param_ios(specs) + [_io("images", (4, cfg.image, cfg.image, cfg.in_ch))],
        kind="fwd", model=cfg.name, batch=4, n_params=len(specs),
    )

    from .kernels import matmul as pmm
    from .kernels import ref as kref

    for tag, f in [("pallas", lambda x, w: (pmm.matmul(x, w),)),
                   ("native", lambda x, w: (kref.matmul_ref(x, w),))]:
        b.add_artifact(f"matmul_{tag}", f,
                       [_io("x", (256, 512)), _io("w", (512, 256))],
                       kind="kernel", batch=256)


def build_sgd(b: Builder):
    """In-graph SGD apply for vgg_tiny — ablation vs rust-side update."""
    specs = cnn.param_specs(cnn.VGG_TINY)
    pios = _param_ios(specs)
    gios = [_io("grad_" + n, s) for n, s in specs]
    b.add_artifact("vgg_tiny_sgd", M.make_sgd_apply(len(specs)),
                   pios + gios + [_io("lr", ())], kind="sgd",
                   model="vgg_tiny", n_params=len(specs))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--large", action="store_true",
                    help="also lower the ~100M-param gpt_large artifacts")
    args = ap.parse_args()
    b = Builder(args.out_dir)
    print("lowering CNN artifacts…")
    build_cnn(b, cnn.VGG_TINY, train_b=4, fwd_b=32, eval_b=64)
    build_cnn(b, cnn.OVERFEAT_TINY, train_b=4, fwd_b=32, eval_b=64)
    print("lowering CD-DNN artifacts…")
    build_cddnn(b, cddnn.CDDNN_TINY, train_b=64, fwd_b=256)
    print("lowering GPT artifacts…")
    build_gpt(b, transformer.GPT_TEST, train_b=2, eval_b=2)
    build_gpt(b, transformer.GPT_MINI, train_b=4, eval_b=8)
    if args.large:
        build_gpt(b, transformer.GPT_LARGE, train_b=2, eval_b=2)
    print("lowering kernel ablation artifacts…")
    build_kernel_ablation(b)
    build_sgd(b)
    b.finish()


if __name__ == "__main__":
    main()
