"""L2 entrypoints: train/score step functions over the model zoo.

Every function here takes *positional* flat arguments (params..., data...)
and returns a tuple — that is the ABI the rust runtime executes against.
The ordering of params is fixed by each model's `param_specs` and recorded
in artifacts/manifest.json by aot.py.

The weight update deliberately does NOT live in these graphs: the paper
places SGD between part-reduce and part-broadcast on the coordinator
(§3.4), so the artifacts return (loss, grad_0, ..., grad_{P-1}) and rust
owns optimizer state and synchronization.
"""

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .models import cddnn, cnn, common, transformer


def _split(args, n_params):
    return list(args[:n_params]), args[n_params:]


def make_cnn_train_step(cfg: cnn.CnnConfig, use_pallas: bool = False) -> Callable:
    """(params..., images f32[N,H,W,C], labels i32[N]) -> (loss, grads...)."""
    n_params = len(cnn.param_specs(cfg))

    def step(*args):
        params, (x, y) = _split(args, n_params)

        def loss_fn(ps):
            return common.cross_entropy(cnn.forward(cfg, ps, x, use_pallas), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return step


def make_cnn_fwd(cfg: cnn.CnnConfig, use_pallas: bool = False) -> Callable:
    """(params..., images) -> (logits,) — the scoring path (Fig 3 'FP')."""
    n_params = len(cnn.param_specs(cfg))

    def fwd(*args):
        params, (x,) = _split(args, n_params)
        return (cnn.forward(cfg, params, x, use_pallas),)

    return fwd


def make_cnn_eval(cfg: cnn.CnnConfig) -> Callable:
    """(params..., images, labels) -> (loss, top1, top5) for validation."""
    n_params = len(cnn.param_specs(cfg))

    def ev(*args):
        params, (x, y) = _split(args, n_params)
        logits = cnn.forward(cfg, params, x)
        k5 = min(5, cfg.classes)
        return (
            common.cross_entropy(logits, y),
            common.accuracy_topk(logits, y, 1),
            common.accuracy_topk(logits, y, k5),
        )

    return ev


def make_cddnn_train_step(cfg: cddnn.CddnnConfig, use_pallas: bool = False) -> Callable:
    """(params..., frames f32[N,in_dim], senones i32[N]) -> (loss, grads...)."""
    n_params = len(cddnn.param_specs(cfg))

    def step(*args):
        params, (x, y) = _split(args, n_params)

        def loss_fn(ps):
            return common.cross_entropy(cddnn.forward(cfg, ps, x, use_pallas), y)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return step


def make_cddnn_fwd(cfg: cddnn.CddnnConfig, use_pallas: bool = False) -> Callable:
    n_params = len(cddnn.param_specs(cfg))

    def fwd(*args):
        params, (x,) = _split(args, n_params)
        return (cddnn.forward(cfg, params, x, use_pallas),)

    return fwd


def make_gpt_train_step(cfg: transformer.GptConfig, use_pallas: bool = False) -> Callable:
    """(params..., tokens i32[N,seq]) -> (loss, grads...)."""
    n_params = len(transformer.param_specs(cfg))

    def step(*args):
        params, (tokens,) = _split(args, n_params)

        def loss_fn(ps):
            return transformer.lm_loss(cfg, ps, tokens, use_pallas)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return (loss, *grads)

    return step


def make_gpt_eval(cfg: transformer.GptConfig) -> Callable:
    """(params..., tokens) -> (loss,) — held-out perplexity probe."""
    n_params = len(transformer.param_specs(cfg))

    def ev(*args):
        params, (tokens,) = _split(args, n_params)
        return (transformer.lm_loss(cfg, params, tokens),)

    return ev


def make_sgd_apply(n_params: int) -> Callable:
    """(params..., grads..., lr f32[]) -> updated params. Kept as an
    artifact so the ablation bench can compare in-graph vs rust-side SGD."""

    def apply(*args):
        params = args[:n_params]
        grads = args[n_params : 2 * n_params]
        lr = args[2 * n_params]
        return tuple(p - lr * g for p, g in zip(params, grads))

    return apply


def make_conv_layer(shape: Tuple[int, int, int, int], wshape, stride: int,
                    padding: str, use_pallas: bool) -> Callable:
    """Single conv layer (x, w) -> (y,) — the L1 kernel ablation artifact."""
    from .kernels import conv2d as pconv
    from .kernels import ref

    def f(x, w):
        if use_pallas:
            return (pconv.conv2d(x, w, stride, padding),)
        return (ref.conv2d_ref(x, w, stride, padding),)

    return f
