"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

These implement the paper's three compute patterns (Algorithm 1 and the
backprop / weight-gradient variants of §2.1) with stock XLA ops. The Pallas
kernels in conv2d.py / matmul.py must match these to ~1e-5 (f32).
"""

import jax
import jax.numpy as jnp


def conv2d_ref(x, w, stride: int = 1, padding: str = "VALID"):
    """NHWC x KHKWIO forward convolution (paper Algorithm 1).

    x: (N, H, W, Cin)   w: (KH, KW, Cin, Cout)  ->  (N, OH, OW, Cout)
    """
    return jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def conv2d_input_grad_ref(dy, w, x_shape, stride: int = 1, padding: str = "VALID"):
    """Backpropagation (paper §2.1): gradient w.r.t. the input activations."""
    _, vjp = jax.vjp(
        lambda x: conv2d_ref(x, w, stride, padding), jnp.zeros(x_shape, dy.dtype)
    )
    return vjp(dy)[0]


def conv2d_weight_grad_ref(x, dy, w_shape, stride: int = 1, padding: str = "VALID"):
    """Weight-gradient update (paper §2.1): gradient w.r.t. the kernel."""
    _, vjp = jax.vjp(
        lambda w: conv2d_ref(x, w, stride, padding), jnp.zeros(w_shape, x.dtype)
    )
    return vjp(dy)[0]


def matmul_ref(x, w, bias=None, relu: bool = False):
    """Fully-connected layer: the k_h=k_w=out_h=out_w=1 special case of
    Algorithm 1 (paper §2.1). Optional fused bias + ReLU epilogue."""
    y = jnp.dot(x, w, preferred_element_type=jnp.float32)
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0.0)
    return y


def maxpool2d_ref(x, window: int = 2, stride: int = 2):
    """2x2 max-pooling used between VGG conv blocks."""
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="VALID",
    )
