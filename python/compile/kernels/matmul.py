"""L1 Pallas kernel: block-SGEMM for fully-connected layers (paper §2.1, §4).

The paper's compute library implements FC layers as "highly efficient
block-SGEMM functions"; this is that kernel, TPU-adapted. M is the
minibatch dim, K the input features, N the output features. Blocking:

  * (block_m x block_n) output tile resident in VMEM (cache block),
  * K consumed in block_k chunks (the ifm-blocked inner loop of §2.4),
  * block_n is the lane dimension (the paper's SIMD-width ofm group).

Optional fused bias+ReLU epilogue — the paper fuses activation into the
SGEMM epilogue to avoid an extra pass over the output.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pick_block(total: int, preferred: int) -> int:
    b = min(preferred, total)
    while total % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, w_ref, o_ref, *, k, bk, relu):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for k0 in range(0, k, bk):
        acc += jax.lax.dot_general(
            x_ref[:, k0 : k0 + bk],
            w_ref[k0 : k0 + bk, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _matmul_bias_kernel(x_ref, w_ref, b_ref, o_ref, *, k, bk, relu):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    for k0 in range(0, k, bk):
        acc += jax.lax.dot_general(
            x_ref[:, k0 : k0 + bk],
            w_ref[k0 : k0 + bk, :],
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def matmul(x, w, bias=None, relu: bool = False, *, block_m: int = 128,
           block_n: int = 128, block_k: int = 512, interpret: bool = True):
    """Blocked matmul: x (M,K) @ w (K,N) [+ bias (N,)] [then ReLU] -> (M,N)."""
    m, k = x.shape
    wk, n = w.shape
    assert k == wk, f"contraction mismatch {k} vs {wk}"
    bm = _pick_block(m, block_m)
    bn = _pick_block(n, block_n)
    bk = _pick_block(k, block_k)
    grid = (m // bm, n // bn)
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)
    if bias is None:
        kernel = functools.partial(_matmul_kernel, k=k, bk=bk, relu=relu)
        in_specs = [
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ]
        args = (x, w)
    else:
        assert bias.shape == (n,)
        kernel = functools.partial(_matmul_bias_kernel, k=k, bk=bk, relu=relu)
        in_specs = [
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ]
        args = (x, w, bias)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
