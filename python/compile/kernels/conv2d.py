"""L1 Pallas kernel: blocked direct convolution (paper §2.2-2.4, TPU-adapted).

The paper's AVX2 strategy — cache-block over ifm/ofm, register-block over
out_h/out_w, SIMD over an ofm group of width SW — maps onto Pallas/TPU as:

  * SIMD width SW (=8, AVX2)      ->  lane dimension: `block_oc` output
                                      features form the minormost tile dim.
  * L2 cache block (128 KB)       ->  VMEM tile selected by BlockSpec:
                                      (block_oh x OW x block_oc) output rows
                                      stay resident while kh/kw/ifm loops run.
  * register block RB_h x RB_w    ->  `block_oh` output rows accumulated in
    of VFMA accumulators              a VMEM accumulator, contracted on the
                                      MXU via dot_general instead of VFMA
                                      chains.
  * ifm-blocked inner loop        ->  `block_ic`-wide contraction chunks.

`interpret=True` is mandatory on this image: real TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute; interpret-mode lowers the
kernel to plain HLO so the same artifact runs everywhere. TPU efficiency is
estimated analytically (VMEM footprint + MXU utilization — see
`repro analyze kernel-blocking` on the rust side), never from interpreted
wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM budget (bytes) used when auto-selecting block shapes. Mirrors the
# paper's Size_cache constraint in the §2.2 minimization, with the TPU
# scratchpad standing in for the Xeon L2 slice. Kept deliberately below the
# real ~16 MB to leave room for double buffering (paper §2.2 notes the same).
VMEM_BUDGET = 8 * 1024 * 1024


def _pick_block(total: int, preferred: int) -> int:
    """Largest divisor of `total` that is <= preferred (>=1)."""
    b = min(preferred, total)
    while total % b != 0:
        b -= 1
    return b


def choose_blocks(oh, ow, cin, cout, kh, kw, dtype_bytes=4, budget=VMEM_BUDGET):
    """Select (block_oh, block_oc, block_ic) minimizing HBM traffic per FLOP
    subject to the VMEM budget — the §2.2 constrained minimization, reduced
    to the three dims this kernel blocks. Exhaustive over divisors (the
    paper uses a brute-force state-space search; ours is the same idea with
    a smaller space because OW and KH/KW are not blocked)."""
    best = None
    oh_divs = [d for d in range(1, oh + 1) if oh % d == 0]
    oc_divs = [d for d in range(1, cout + 1) if cout % d == 0]
    ic_divs = [d for d in range(1, cin + 1) if cin % d == 0]
    for boh in oh_divs:
        for boc in oc_divs:
            # VMEM residents: output tile, full-width input rows needed by
            # the tile, and the (kh,kw,cin,boc) weight slice.
            out_b = boh * ow * boc
            in_b = (boh + kh - 1) * (ow + kw - 1) * cin
            wt_b = kh * kw * cin * boc
            bs = dtype_bytes * (out_b + in_b + wt_b) * 2  # x2: double buffer
            if bs > budget:
                continue
            flops = 2 * boh * ow * boc * cin * kh * kw
            bf = dtype_bytes * (out_b + in_b + wt_b) / flops
            key = (bf, -boc)  # tie-break: widest lane dim
            if best is None or key < best[0]:
                best = (key, (boh, boc))
    if best is None:  # nothing fits: fall back to minimum tile
        boh, boc = 1, _pick_block(cout, 128)
    else:
        boh, boc = best[1]
    bic = _pick_block(cin, 128)
    return boh, boc, bic


def _conv_kernel(x_ref, w_ref, o_ref, *, kh, kw, cin, ow, stride, boh, boc, bic):
    """One grid program: produce the (boh, OW, boc) output tile for image n,
    ofm-block oc, row-block oh (grid = (N, Cout/boc, OH/boh)).

    Mirrors Algorithm 2: the accumulator tile plays the role of the vout[]
    register block; the kh/kw/ifm loops are the i5..i7 loops; the
    dot_general is the broadcast-VFMA inner pair, executed on the MXU.
    """
    oh_idx = pl.program_id(2)
    acc = jnp.zeros((boh * ow, boc), jnp.float32)
    for i5 in range(kh):
        for i6 in range(kw):
            row_start = oh_idx * (boh * stride) + i5
            rows = x_ref[
                pl.ds(row_start, (boh - 1) * stride + 1),
                pl.ds(i6, (ow - 1) * stride + 1),
                :,
            ]
            patch = rows[::stride, ::stride, :]  # (boh, OW, Cin)
            pm = patch.reshape(boh * ow, cin)
            for c0 in range(0, cin, bic):  # ifm-blocked contraction (§2.4)
                acc += jax.lax.dot_general(
                    pm[:, c0 : c0 + bic],
                    w_ref[i5, i6, c0 : c0 + bic, :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32,
                )
    o_ref[...] = acc.reshape(boh, ow, boc)


def conv2d(x, w, stride: int = 1, padding: str = "VALID", *, block_oh=None,
           block_oc=None, block_ic=None, interpret: bool = True):
    """Blocked direct convolution. x: (N,H,W,Cin) f32, w: (KH,KW,Cin,Cout).

    Matches ref.conv2d_ref. Padding is materialized outside the kernel so
    the BlockSpec schedule stays a pure VALID sliding window.
    """
    n, h, wd, cin = x.shape
    kh, kw, wcin, cout = w.shape
    assert cin == wcin, f"channel mismatch {cin} vs {wcin}"
    if padding == "SAME":
        assert stride == 1, "SAME padding supported for stride 1"
        ph, pw = kh // 2, kw // 2
        x = jnp.pad(x, ((0, 0), (ph, kh - 1 - ph), (pw, kw - 1 - pw), (0, 0)))
        n, h, wd, cin = x.shape
    elif padding != "VALID":
        raise ValueError(f"unsupported padding {padding!r}")
    oh = (h - kh) // stride + 1
    ow = (wd - kw) // stride + 1
    assert oh >= 1 and ow >= 1

    auto = choose_blocks(oh, ow, cin, cout, kh, kw)
    boh = block_oh if block_oh is not None else auto[0]
    boc = block_oc if block_oc is not None else auto[1]
    bic = block_ic if block_ic is not None else auto[2]
    boh = _pick_block(oh, boh)
    boc = _pick_block(cout, boc)
    bic = _pick_block(cin, bic)

    kernel = functools.partial(
        _conv_kernel, kh=kh, kw=kw, cin=cin, ow=ow, stride=stride,
        boh=boh, boc=boc, bic=bic,
    )
    return pl.pallas_call(
        kernel,
        grid=(n, cout // boc, oh // boh),
        in_specs=[
            pl.BlockSpec((None, h, wd, cin), lambda i, j, k: (i, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, boc), lambda i, j, k: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((None, boh, ow, boc), lambda i, j, k: (i, k, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, oh, ow, cout), jnp.float32),
        interpret=interpret,
    )(x, w)
