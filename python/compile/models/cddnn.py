"""CD-DNN acoustic model (paper §5.4): fully-connected DNN-HMM frontend.

The paper's network (Seide et al. 2011) is 7 hidden layers x 2048 units,
input = 429 (11-frame context x 39 MFCC features), output = senone set
(~9304). That full-size descriptor lives on the rust side for the analytic
scaling model (Fig 7). Here we define a runnable scaled variant with the
same depth (7 hidden FC layers — depth is what stresses the FC/hybrid
communication path) for real training runs.
"""

import dataclasses
from typing import List, Tuple

from ..kernels import matmul as pmm
from ..kernels import ref
from . import common


@dataclasses.dataclass(frozen=True)
class CddnnConfig:
    name: str
    in_dim: int
    hidden: int
    n_hidden: int
    senones: int


# Paper-scale (analytic only): 429 -> 7x2048 -> 9304.
CDDNN_FULL = CddnnConfig("cddnn_full", 429, 2048, 7, 9304)
# Runnable: same depth, 1/8 width, 128 senone classes.
CDDNN_TINY = CddnnConfig("cddnn_tiny", 429, 256, 7, 128)


def param_specs(cfg: CddnnConfig) -> List[common.ParamSpec]:
    specs = []
    width = cfg.in_dim
    for i in range(cfg.n_hidden):
        specs.append((f"h{i}.w", (width, cfg.hidden)))
        specs.append((f"h{i}.b", (cfg.hidden,)))
        width = cfg.hidden
    specs.append(("senone.w", (width, cfg.senones)))
    specs.append(("senone.b", (cfg.senones,)))
    return specs


def init_params(cfg: CddnnConfig, key):
    return common.init_from_specs(param_specs(cfg), key)


def forward(cfg: CddnnConfig, params, x, use_pallas: bool = False):
    """Senone logits for a batch of frames x: (N, in_dim) f32."""
    mm = pmm.matmul if use_pallas else ref.matmul_ref
    i = 0
    for _ in range(cfg.n_hidden):
        w, b = params[i], params[i + 1]
        i += 2
        x = mm(x, w, b, relu=True)
    w, b = params[i], params[i + 1]
    return mm(x, w, b, relu=False)
