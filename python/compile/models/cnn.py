"""Configurable CNN family: tiny VGG-A and tiny OverFeat-FAST variants.

The paper evaluates VGG-A (Simonyan & Zisserman) and OverFeat-FAST
(Sermanet et al.) at ImageNet scale. The rust side keeps *full-size* layer
descriptors for the analytic models (Table 1, Figs 3/4/6); here we define
runnable scaled-down counterparts with the same architectural shape
(conv pyramid with monotonically shrinking feature maps + FC head) for the
real multi-worker training runs (Fig 5 convergence equivalence, e2e).
"""

import dataclasses
from typing import List, Tuple

import jax.numpy as jnp

from ..kernels import conv2d as pconv
from ..kernels import matmul as pmm
from ..kernels import ref
from . import common
from .common import ConvSpec


@dataclasses.dataclass(frozen=True)
class CnnConfig:
    name: str
    image: int  # square input, NHWC
    in_ch: int
    convs: Tuple[ConvSpec, ...]
    fcs: Tuple[int, ...]  # hidden FC widths
    classes: int

    @property
    def conv_out_hw(self) -> int:
        hw = self.image
        for c in self.convs:
            if c.padding == "SAME":
                pass
            else:
                hw = (hw - c.k) // c.stride + 1
            if c.padding == "SAME" and c.stride != 1:
                raise ValueError("SAME conv must be stride 1 here")
            if c.pool:
                hw //= 2
        return hw

    @property
    def conv_out_ch(self) -> int:
        return self.convs[-1].out


# VGG-A shrunk 8x in channels, 32x32 input, 8 weight-layer conv pyramid with
# 5 pool stages — same depth/shape as the paper's VGG-A, laptop-scale flops.
VGG_TINY = CnnConfig(
    name="vgg_tiny",
    image=32,
    in_ch=3,
    convs=(
        ConvSpec(3, 8, pool=True),
        ConvSpec(3, 16, pool=True),
        ConvSpec(3, 32),
        ConvSpec(3, 32, pool=True),
        ConvSpec(3, 64),
        ConvSpec(3, 64, pool=True),
        ConvSpec(3, 64),
        ConvSpec(3, 64, pool=True),
    ),
    fcs=(128, 64),
    classes=10,
)

# OverFeat-FAST shrunk: strided first conv (the 11x11/s4 C1 analogue),
# VALID interior convs, big FC head relative to conv trunk — preserves the
# property the paper leans on (OverFeat has ~7x lower comp/comm than VGG-A).
OVERFEAT_TINY = CnnConfig(
    name="overfeat_tiny",
    image=32,
    in_ch=3,
    convs=(
        ConvSpec(5, 16, stride=2, padding="VALID", pool=True),  # 32->14->7
        ConvSpec(3, 32, padding="VALID"),  # 7->5
        ConvSpec(3, 64),
        ConvSpec(3, 64),
    ),
    fcs=(192, 96),
    classes=10,
)


def param_specs(cfg: CnnConfig) -> List[common.ParamSpec]:
    specs = []
    ch = cfg.in_ch
    for i, c in enumerate(cfg.convs):
        specs.append((f"conv{i}.w", (c.k, c.k, ch, c.out)))
        specs.append((f"conv{i}.b", (c.out,)))
        ch = c.out
    width = cfg.conv_out_hw * cfg.conv_out_hw * cfg.conv_out_ch
    for i, w in enumerate(cfg.fcs):
        specs.append((f"fc{i}.w", (width, w)))
        specs.append((f"fc{i}.b", (w,)))
        width = w
    specs.append(("head.w", (width, cfg.classes)))
    specs.append(("head.b", (cfg.classes,)))
    return specs


def init_params(cfg: CnnConfig, key):
    return common.init_from_specs(param_specs(cfg), key)


def forward(cfg: CnnConfig, params, x, use_pallas: bool = False):
    """Logits for a batch of images x: (N, image, image, in_ch) f32."""
    conv = pconv.conv2d if use_pallas else ref.conv2d_ref
    mm = pmm.matmul if use_pallas else ref.matmul_ref
    i = 0
    for c in cfg.convs:
        w, b = params[i], params[i + 1]
        i += 2
        x = conv(x, w, c.stride, c.padding)
        x = jnp.maximum(x + b, 0.0)
        if c.pool:
            x = ref.maxpool2d_ref(x)
    x = x.reshape(x.shape[0], -1)
    for _ in cfg.fcs:
        w, b = params[i], params[i + 1]
        i += 2
        x = mm(x, w, b, relu=True)
    w, b = params[i], params[i + 1]
    return mm(x, w, b, relu=False)
