"""Shared building blocks for the L2 model zoo.

Parameters are *ordered lists* of arrays (with a parallel spec list of
(name, shape)) rather than pytrees: the AOT boundary between python and the
rust coordinator is positional, so a deterministic flat order is part of
the artifact ABI (recorded in artifacts/manifest.json).
"""

import dataclasses
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp

ParamSpec = Tuple[str, Tuple[int, ...]]


def he_normal(key, shape, fan_in):
    """He-normal initializer (ReLU networks)."""
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def init_from_specs(specs: Sequence[ParamSpec], key) -> List[jnp.ndarray]:
    """Initialize every spec: weights He-normal (fan-in = prod of all dims
    but the last), biases/gains zeros/ones by name convention."""
    params = []
    for name, shape in specs:
        key, sub = jax.random.split(key)
        if name.endswith(".b") or name.endswith(".bias"):
            params.append(jnp.zeros(shape, jnp.float32))
        elif name.endswith(".g") or name.endswith(".gain"):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith("emb.w"):
            # GPT-style embedding init: the token table doubles as the
            # tied LM head, so He-by-fan-in would inflate initial logits
            # by ~sqrt(d_model); sigma=0.02 is the standard choice.
            params.append(jax.random.normal(sub, shape, jnp.float32) * 0.02)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(he_normal(sub, shape, max(fan_in, 1)))
    return params


def cross_entropy(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)
    return jnp.mean(logz - gold[..., 0])


def accuracy_topk(logits, labels, k: int = 1):
    """Top-k accuracy (Fig 5 reports Top-5).

    Expressed as a rank count (gold is top-k iff fewer than k logits
    strictly exceed it) rather than `lax.top_k`: jax lowers top_k to an
    HLO `topk(..., largest=true)` attribute that xla_extension 0.5.1's
    text parser rejects, and comparisons+reductions lower cleanly.
    """
    gold = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)
    rank = jnp.sum((logits > gold).astype(jnp.int32), axis=-1)
    return jnp.mean((rank < k).astype(jnp.float32))


def layer_norm(x, gain, bias, eps: float = 1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gain + bias


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One convolutional layer: kernel k x k, `out` ofm, stride, padding,
    optionally followed by a 2x2 maxpool (the VGG block boundary)."""

    k: int
    out: int
    stride: int = 1
    padding: str = "SAME"
    pool: bool = False
