"""Decoder-only transformer LM for the end-to-end training driver.

Not from the paper (which predates transformers) — this is the
system-prompt-mandated e2e workload proving all layers compose: the rust
coordinator trains this model with synchronous data-parallel SGD through
the same part-reduce/part-broadcast path used for the paper's CNN/DNN
topologies. FC-heavy like the paper's ASR network, so it also exercises
the hybrid-parallel analysis on a modern workload.

Pre-LN GPT-2-style blocks, learned positional embeddings, weight-tied LM
head, no dropout (training must be bitwise-deterministic for the
convergence-equivalence claim).
"""

import dataclasses
from typing import List

import jax.numpy as jnp

from ..kernels import matmul as pmm
from ..kernels import ref
from . import common


@dataclasses.dataclass(frozen=True)
class GptConfig:
    name: str
    vocab: int
    seq: int
    d_model: int
    n_heads: int
    n_layers: int

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_params(self) -> int:
        d = self.d_model
        return (self.vocab + self.seq) * d + self.n_layers * (12 * d * d + 13 * d) + 2 * d


# ~11M params: the default e2e run (1 CPU core; see EXPERIMENTS.md).
GPT_MINI = GptConfig("gpt_mini", vocab=128, seq=64, d_model=384, n_heads=6, n_layers=6)
# ~100M-class config for the scaled e2e run.
GPT_LARGE = GptConfig("gpt_large", vocab=4096, seq=128, d_model=768, n_heads=12, n_layers=12)
# Small config for tests/quick artifacts.
GPT_TEST = GptConfig("gpt_test", vocab=64, seq=16, d_model=64, n_heads=4, n_layers=2)


def param_specs(cfg: GptConfig) -> List[common.ParamSpec]:
    d = cfg.d_model
    specs = [("tok_emb.w", (cfg.vocab, d)), ("pos_emb.w", (cfg.seq, d))]
    for i in range(cfg.n_layers):
        p = f"block{i}."
        specs += [
            (p + "ln1.g", (d,)),
            (p + "ln1.b", (d,)),
            (p + "attn.wqkv", (d, 3 * d)),
            (p + "attn.bqkv", (3 * d,)),
            (p + "attn.wo", (d, d)),
            (p + "attn.bo", (d,)),
            (p + "ln2.g", (d,)),
            (p + "ln2.b", (d,)),
            (p + "mlp.w1", (d, 4 * d)),
            (p + "mlp.b1", (4 * d,)),
            (p + "mlp.w2", (4 * d, d)),
            (p + "mlp.b2", (d,)),
        ]
    specs += [("lnf.g", (d,)), ("lnf.b", (d,))]
    return specs


def init_params(cfg: GptConfig, key):
    return common.init_from_specs(param_specs(cfg), key)


def _attention(cfg: GptConfig, x, wqkv, bqkv, wo, bo, mm):
    n, t, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    qkv = mm(x.reshape(n * t, d), wqkv, bqkv).reshape(n, t, 3, h, dh)
    q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # (n, h, t, dh)
    k = qkv[:, :, 1].transpose(0, 2, 1, 3)
    v = qkv[:, :, 2].transpose(0, 2, 1, 3)
    att = jnp.einsum("nhqd,nhkd->nhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    att = jnp.where(mask[None, None], att, -1e30)
    att = att - att.max(axis=-1, keepdims=True)
    p = jnp.exp(att)
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("nhqk,nhkd->nhqd", p, v).transpose(0, 2, 1, 3).reshape(n * t, d)
    return mm(out, wo, bo).reshape(n, t, d)


def forward(cfg: GptConfig, params, tokens, use_pallas: bool = False):
    """Next-token logits. tokens: (N, seq) int32 -> (N, seq, vocab) f32.

    The LM head is tied to tok_emb (saves vocab*d params and matches
    standard practice for small LMs).
    """
    mm = pmm.matmul if use_pallas else ref.matmul_ref
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    n, t = tokens.shape
    x = tok_emb[tokens] + pos_emb[None, :t]
    for _ in range(cfg.n_layers):
        ln1g, ln1b = next(it), next(it)
        wqkv, bqkv, wo, bo = next(it), next(it), next(it), next(it)
        ln2g, ln2b = next(it), next(it)
        w1, b1, w2, b2 = next(it), next(it), next(it), next(it)
        h = common.layer_norm(x, ln1g, ln1b)
        x = x + _attention(cfg, h, wqkv, bqkv, wo, bo, mm)
        h = common.layer_norm(x, ln2g, ln2b)
        d = cfg.d_model
        h2 = mm(h.reshape(n * t, d), w1, b1, relu=True)
        h2 = mm(h2, w2, b2).reshape(n, t, d)
        x = x + h2
    lnfg, lnfb = next(it), next(it)
    x = common.layer_norm(x, lnfg, lnfb)
    return ref.matmul_ref(x.reshape(n * t, cfg.d_model), tok_emb.T).reshape(
        n, t, cfg.vocab
    )


def lm_loss(cfg: GptConfig, params, tokens, use_pallas: bool = False):
    """Causal LM loss: predict tokens[:, 1:] from tokens[:, :-1]."""
    logits = forward(cfg, params, tokens, use_pallas)
    return common.cross_entropy(logits[:, :-1].reshape(-1, cfg.vocab),
                                tokens[:, 1:].reshape(-1))
